//! `amq` — launcher for the alternating-multi-bit-quantization stack.
//!
//! Subcommands:
//! ```text
//! amq serve    [--config f.toml | --addr .. --w-bits 2 --a-bits 2 --threads N --kernel auto
//!               --event-loop --loops N --max-slots N --queue-depth N --continuous
//!               --model name=path.amqz (repeatable) --model-alias alias=name
//!               --default-model name --model-mem-budget 512mb
//!               --request-deadline-ms N --session-ttl-secs N --write-stall-ms N ..]
//! amq publish  --out f.amqz [--checkpoint f.amqt | --random] --w-bits 2 --a-bits 2 ...
//! amq train    --tag lstm_fp [--dataset ptb|wt2|text8] [--epochs N] ...
//! amq quantize --bits 2 [--method alternating[:cycles]] [--checkpoint f.amqt]
//! amq bench    table1|table2|table3|table4|table5|table6|table7|table8|table9|costmodel
//! amq stats    --addr host:port [--text]  (query a running server's STATS)
//! amq kernels  (print active/available kernel backends, CPU features, tiling)
//! ```
//!
//! `--event-loop` swaps the thread-per-connection front end for the
//! multiplexed epoll/kqueue event loop (`server::eventloop`) and switches
//! the batcher to continuous batching; `--max-slots` caps concurrently
//! decoding sequences and `--queue-depth` bounds the admission queue
//! before `ERR BUSY` load shedding. `--continuous` enables continuous
//! batching on the classic front end too. `AMQ_EVENTLOOP=1` in the
//! environment forces `--event-loop` (CI uses this to run both front ends
//! through one script).
//!
//! `amq publish` quantizes a model once and writes the packed `.amqz`
//! format (`data::amqz`) — the exact in-memory bit-plane layout, so
//! `amq serve --model name=path.amqz` brings it up with a single bulk read
//! instead of re-quantizing. Multiple `--model` flags (or a `[models]`
//! config section) serve several models from one process; requests pick
//! one with the protocol's `MODEL <name>` field, and idle models LRU-evict
//! past `--model-mem-budget`.
//!
//! Robustness knobs (all default off): `--request-deadline-ms` answers
//! `ERR DEADLINE` at the next timestep boundary once a request overstays,
//! `--session-ttl-secs` reaps idle sessions as if `END` arrived, and
//! `--write-stall-ms` (event loop) closes connections that stop reading
//! their replies. `AMQ_FAULTS` (testing only) injects deterministic faults
//! — see `server::faults`.
//!
//! Zero-downtime ops: `--snapshot <f.amqs>` arms graceful drain — a `DRAIN`
//! line or SIGTERM stops admission (`ERR DRAINING`), finishes in-flight
//! decodes up to `--drain-deadline-ms`, and serializes live sessions to the
//! checksummed snapshot; `--restore <f.amqs>` revives them at the next
//! start, continuing bit-exactly. `HEALTH` answers `ok|degraded|draining`
//! front-end-side even when the batcher thread is wedged.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use amq::cli::Cli;
use amq::config::{parse_mem_size, Config, ModelConfig, ServerConfig};
use amq::data::{amqz, Corpus, DatasetSpec};
use amq::exec::{Exec, ExecConfig};
use amq::exp;
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnLm};
use amq::model::RnnKind;
use amq::quant::{self, Method};
use amq::server::batcher::Work;
use amq::server::{tcp, BatcherConfig, InferenceServer, ModelRegistry};
use amq::util::Rng;
use anyhow::{bail, Context, Result};

fn main() {
    let cli = match Cli::from_env() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n{}", usage());
            std::process::exit(2);
        }
    };
    let code = match run(cli) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> &'static str {
    "usage: amq <serve|publish|train|quantize|bench|stats|kernels> [options]\n\
     run `amq <subcommand> --help` conventions in README.md"
}

fn run(cli: Cli) -> Result<()> {
    match cli.subcommand.as_str() {
        "serve" => cmd_serve(&cli),
        "publish" => cmd_publish(&cli),
        "train" => cmd_train(&cli),
        "quantize" => cmd_quantize(&cli),
        "bench" => cmd_bench(&cli),
        "stats" => cmd_stats(&cli),
        "kernels" => cmd_kernels(&cli),
        "" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}

fn artifact_dir(cli: &Cli) -> PathBuf {
    PathBuf::from(cli.get_str("artifacts", "artifacts"))
}

fn runs_dir(cli: &Cli) -> PathBuf {
    let d = PathBuf::from(cli.get_str("runs", "runs"));
    let _ = std::fs::create_dir_all(&d);
    d
}

fn dataset(cli: &Cli) -> Result<DatasetSpec> {
    let scale = cli.get_usize("scale", 8)?;
    Ok(match cli.get_str("dataset", "ptb").as_str() {
        "ptb" => DatasetSpec::ptb_like().scaled(scale, 5),
        "wt2" => DatasetSpec::wt2_like().scaled(scale * 2, 17),
        "text8" => DatasetSpec::text8_like().scaled(scale * 16, 21),
        other => bail!("unknown dataset '{other}' (ptb|wt2|text8)"),
    })
}

// ---------------------------------------------------------------------------

fn cmd_serve(cli: &Cli) -> Result<()> {
    let file_cfg = match cli.get("config") {
        Some(path) => Some(Config::load(std::path::Path::new(path))?),
        None => None,
    };
    let (server_cfg, model_cfg) = if let Some(c) = &file_cfg {
        (ServerConfig::from_config(c), ModelConfig::from_config(c)?)
    } else {
        let c = Config::parse("")?;
        let mut m = ModelConfig::from_config(&c)?;
        m.w_bits = cli.get_usize("w-bits", 2)?;
        m.a_bits = cli.get_usize("a-bits", 2)?;
        m.quantized = m.w_bits > 0;
        m.lm.vocab = cli.get_usize("vocab", 2000)?;
        m.lm.hidden = cli.get_usize("hidden", 200)?;
        let mut s = ServerConfig::from_config(&c);
        s.addr = cli.get_str("addr", &s.addr);
        s.max_batch = cli.get_usize("max-batch", s.max_batch)?;
        (s, m)
    };
    let mut server_cfg = server_cfg;
    // Serving-shape flags override the config file (like --threads).
    // `AMQ_EVENTLOOP=1` forces the event-loop front end — lets CI (and
    // anyone scripting both front ends) flip it without editing commands.
    if cli.has("event-loop") || std::env::var("AMQ_EVENTLOOP").is_ok_and(|v| v == "1") {
        server_cfg.event_loop = true;
    }
    server_cfg.loops = cli.get_usize("loops", server_cfg.loops)?;
    server_cfg.max_slots = cli.get_usize("max-slots", server_cfg.max_slots)?;
    server_cfg.queue_depth = cli.get_usize("queue-depth", server_cfg.queue_depth)?;
    server_cfg.request_deadline_ms =
        cli.get_usize("request-deadline-ms", server_cfg.request_deadline_ms as usize)? as u64;
    server_cfg.session_ttl_secs =
        cli.get_usize("session-ttl-secs", server_cfg.session_ttl_secs as usize)? as u64;
    server_cfg.write_stall_ms =
        cli.get_usize("write-stall-ms", server_cfg.write_stall_ms as usize)? as u64;
    if let Some(p) = cli.get("snapshot") {
        server_cfg.snapshot = Some(p.to_string());
    }
    server_cfg.drain_deadline_ms =
        cli.get_usize("drain-deadline-ms", server_cfg.drain_deadline_ms as usize)? as u64;
    // Deterministic fault injection (testing only): `AMQ_FAULTS` parses
    // into a plan threaded through the batcher, registry, and event loop.
    let faults = amq::server::FaultPlan::from_env().map_err(anyhow::Error::msg)?;
    if faults.is_some() {
        eprintln!("warning: AMQ_FAULTS is set — deterministic fault injection is ACTIVE");
    }
    // The event loop multiplexes many clients onto one Work channel; it
    // only makes sense with continuous batching, so it implies it.
    let continuous = server_cfg.event_loop || cli.has("continuous");

    // Kernel backend: `--kernel` (when present — including an explicit
    // `--kernel auto`) overrides `server.kernel`. A named choice is forced
    // process-wide BEFORE the model is built (so every PreparedGemm
    // resolves to it); `auto` falls through to `AMQ_KERNEL` / runtime
    // detection.
    let kernel_choice = if cli.has("kernel") {
        cli.get_kernel("kernel")?
    } else {
        amq::kernels::Kernel::parse_choice(&server_cfg.kernel)
            .map_err(|e| anyhow::anyhow!("server.kernel: {e}"))?
    };
    if let Some(k) = kernel_choice {
        amq::kernels::backend::force(k);
    }
    let kernel = amq::kernels::backend::active();

    // `--threads` overrides the config file; 1 = serial, 0 = auto.
    let exec_cfg = ExecConfig::with_threads(cli.get_usize("threads", server_cfg.threads)?);
    let exec = Exec::new(exec_cfg);

    // Named `.amqz` models for the multi-tenant registry: `--model
    // name=path` (repeatable) plus the `[models]` / `[model_aliases]`
    // config sections. Given any, the server loads packed models on demand
    // instead of building one in process.
    let mut named: Vec<(String, PathBuf)> = Vec::new();
    if let Some(c) = &file_cfg {
        for (name, v) in c.section("models") {
            let p = v
                .as_str()
                .with_context(|| format!("[models] {name} must be a string path"))?;
            named.push((name, PathBuf::from(p)));
        }
    }
    for spec in cli.get_all("model") {
        let (name, path) = spec
            .split_once('=')
            .with_context(|| format!("--model expects name=path.amqz, got '{spec}'"))?;
        named.push((name.to_string(), PathBuf::from(path)));
    }
    let mut aliases: Vec<(String, String)> = Vec::new();
    if let Some(c) = &file_cfg {
        for (alias, v) in c.section("model_aliases") {
            let t = v
                .as_str()
                .with_context(|| format!("[model_aliases] {alias} must be a model name"))?;
            aliases.push((alias, t.to_string()));
        }
    }
    for spec in cli.get_all("model-alias") {
        let (alias, target) = spec
            .split_once('=')
            .with_context(|| format!("--model-alias expects alias=name, got '{spec}'"))?;
        aliases.push((alias.to_string(), target.to_string()));
    }
    let budget_raw = cli
        .get("model-mem-budget")
        .map(str::to_string)
        .or_else(|| server_cfg.model_mem_budget.clone());
    let budget = match &budget_raw {
        Some(s) => parse_mem_size(s).context("--model-mem-budget")?,
        None => 0,
    };

    let batcher_cfg = BatcherConfig {
        max_batch: server_cfg.max_batch,
        batch_wait: std::time::Duration::from_micros(server_cfg.batch_wait_us),
        max_sessions: server_cfg.max_sessions,
        continuous,
        max_slots: server_cfg.max_slots,
        queue_depth: server_cfg.queue_depth,
        exec: exec_cfg,
        request_deadline: (server_cfg.request_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(server_cfg.request_deadline_ms)),
        session_ttl: (server_cfg.session_ttl_secs > 0)
            .then(|| std::time::Duration::from_secs(server_cfg.session_ttl_secs)),
        faults: faults.clone(),
        snapshot_path: server_cfg.snapshot.as_ref().map(PathBuf::from),
        drain_deadline: std::time::Duration::from_millis(server_cfg.drain_deadline_ms),
    };
    let server = if named.is_empty() {
        // Single-model path: build (or load a checkpoint) in process; the
        // batcher pins it as model "default".
        let policy = if model_cfg.quantized {
            PrecisionPolicy::quantized(model_cfg.w_bits, model_cfg.a_bits)
        } else {
            PrecisionPolicy::full()
        };
        let model = match &model_cfg.checkpoint {
            Some(p) => {
                let ckpt = amq::data::checkpoint::Checkpoint::load(std::path::Path::new(p))?;
                let w = amq::train::trainer::weights_from_checkpoint(&ckpt, &model_cfg.lm)?;
                RnnLm::from_weights_exec(model_cfg.lm, &w, policy, &exec)
            }
            None => {
                eprintln!("note: no checkpoint configured — serving a randomly initialized model");
                RnnLm::random_exec(model_cfg.lm, model_cfg.seed, policy, &exec)
            }
        };
        let tile = model
            .a_bits()
            .map(|a| amq::kernels::binary::serving_tile_cols(model.config.hidden, a).to_string())
            .unwrap_or_else(|| "-".into());
        eprintln!(
            "model: {} vocab={} hidden={} {} ({} weight bytes, kernel={}, l2={}KB \
             tile_cols={tile}, {} exec threads)",
            model.config.kind.name(),
            model.config.vocab,
            model.config.hidden,
            if model_cfg.quantized {
                format!("W{}A{}", model_cfg.w_bits, model_cfg.a_bits)
            } else {
                "FP".into()
            },
            model.bytes(),
            amq::kernels::backend::describe(kernel),
            amq::kernels::cost::l2_bytes() / 1024,
            exec.threads()
        );
        InferenceServer::with_exec(Arc::new(model), batcher_cfg, exec)
    } else {
        let mut registry = ModelRegistry::new(budget);
        for (name, path) in &named {
            registry.register_path(name, path.clone()).map_err(anyhow::Error::msg)?;
        }
        for (alias, target) in &aliases {
            registry.alias(alias, target).map_err(anyhow::Error::msg)?;
        }
        if let Some(d) = cli.get("default-model") {
            registry.set_default(d).map_err(anyhow::Error::msg)?;
        } else {
            // No explicit default: the first registered model serves
            // requests that omit the MODEL field.
            let first = named.first().map(|(n, _)| n.clone()).expect("named is non-empty");
            registry.set_default(&first).map_err(anyhow::Error::msg)?;
        }
        // Preload the default so a bad path or corrupt file fails at
        // startup instead of on the first request.
        let default =
            registry.default_name().map(str::to_string).context("no models registered")?;
        let t0 = Instant::now();
        let (model, _) = registry.acquire(&default, |_| true).map_err(anyhow::Error::msg)?;
        let tile = model
            .a_bits()
            .map(|a| amq::kernels::binary::serving_tile_cols(model.config.hidden, a).to_string())
            .unwrap_or_else(|| "-".into());
        eprintln!(
            "registry: {} models, default '{default}' ({} vocab={} hidden={}, {} bytes, \
             loaded in {:.1} ms), budget {} (kernel={}, l2={}KB tile_cols={tile}, \
             {} exec threads)",
            named.len(),
            model.config.kind.name(),
            model.config.vocab,
            model.config.hidden,
            model.bytes(),
            t0.elapsed().as_secs_f64() * 1e3,
            if budget == 0 { "unlimited".to_string() } else { format!("{budget} bytes") },
            amq::kernels::backend::describe(kernel),
            amq::kernels::cost::l2_bytes() / 1024,
            exec.threads()
        );
        InferenceServer::with_registry(registry, batcher_cfg, exec)
    };
    let mut server = server;
    // `--restore <f.amqs>`: revive the sessions a previous instance drained
    // into its snapshot, before any request can race them. Refusing (dirty
    // store, checksum mismatch, shape mismatch) is a startup error — a
    // half-restored server would silently violate bit-exactness.
    if let Some(p) = cli.get("restore") {
        let n = server
            .restore_sessions(std::path::Path::new(p))
            .map_err(anyhow::Error::msg)
            .with_context(|| format!("--restore {p}"))?;
        eprintln!("restored {n} session(s) from {p}");
    }
    let health = server.health.clone();
    let (tx, rx) = mpsc::channel::<Work>();
    let counters = server.counters.clone();
    let batcher = std::thread::spawn(move || server.run(rx));
    eprintln!(
        "serving on {} ({} batching, {} front end)",
        server_cfg.addr,
        if continuous { "continuous" } else { "grouped" },
        if server_cfg.event_loop { "event-loop" } else { "thread-per-conn" },
    );
    #[cfg(unix)]
    term::install();
    if server_cfg.event_loop {
        #[cfg(unix)]
        {
            let srv = amq::server::eventloop::serve(
                &server_cfg.addr,
                tx.clone(),
                amq::server::eventloop::EventLoopConfig {
                    loops: server_cfg.loops,
                    write_stall: (server_cfg.write_stall_ms > 0)
                        .then(|| std::time::Duration::from_millis(server_cfg.write_stall_ms)),
                    counters: Some(counters),
                    faults,
                    health: Some(health),
                },
            )?;
            eprintln!("bound {} (event loop)", srv.addr);
            // Serve until SIGTERM: drain live sessions into the snapshot,
            // then shut the loops down. Without a signal this loop is the
            // old "serve until killed" behavior.
            loop {
                if term::fired() {
                    drain_on_term(&tx);
                    srv.shutdown();
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
            let _ = tx.send(Work::Shutdown);
            let _ = batcher.join();
            return Ok(());
        }
        #[cfg(not(unix))]
        bail!("--event-loop needs epoll/kqueue (unix-only); use the default front end");
    }
    let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
    #[cfg(unix)]
    {
        // SIGTERM watcher: drain, then flip the accept loop's flag so
        // `serve` joins its handlers and returns.
        let flag = shutdown.clone();
        let drain_tx = tx.clone();
        std::thread::spawn(move || loop {
            if term::fired() {
                drain_on_term(&drain_tx);
                flag.store(true, std::sync::atomic::Ordering::SeqCst);
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
    }
    let res = tcp::serve_with_health(&server_cfg.addr, tx.clone(), shutdown, Some(health), |a| {
        eprintln!("bound {a}")
    });
    let _ = tx.send(Work::Shutdown);
    let _ = batcher.join();
    res
}

/// Send `DRAIN` to the batcher on SIGTERM and report the outcome — the
/// same path a `DRAIN` wire line takes, so kill-initiated and
/// operator-initiated drains are indistinguishable to the snapshot.
fn drain_on_term(tx: &mpsc::Sender<Work>) {
    eprintln!("SIGTERM: draining…");
    let (rtx, rrx) = mpsc::channel();
    if tx.send(Work::Drain { respond: amq::server::Respond::Channel(rtx) }).is_err() {
        eprintln!("drain: batcher already gone");
        return;
    }
    match rrx.recv() {
        Ok(reply) => eprintln!("drain: {}", amq::server::protocol::format_reply(&reply)),
        Err(_) => eprintln!("drain: batcher dropped the request"),
    }
}

/// SIGTERM latch: raw `signal(2)` against libc (same std-only FFI spirit
/// as the event loop's poller) flips an atomic the serving loops poll. A
/// handler may only do async-signal-safe work, so the drain itself runs on
/// a normal thread that watches the latch.
#[cfg(unix)]
mod term {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(SIGTERM, on_term);
        }
    }

    pub fn fired() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// Print the kernel-backend inventory: the resolved active backend (with
/// the AVX-512 arm when that is what's running), every backend this host
/// can execute, the detected CPU features, and the cache parameters the
/// batched GEMM tiles against. CI greps this output to decide whether a
/// forced `AMQ_KERNEL=avx512` test leg can run on the host; respects
/// `AMQ_KERNEL` / `AMQ_L2_KB` like the server.
fn cmd_kernels(_cli: &Cli) -> Result<()> {
    use amq::kernels::backend;
    println!("active: {}", backend::describe(backend::active()));
    println!(
        "available: {}",
        backend::available().iter().map(|k| k.name()).collect::<Vec<_>>().join(" ")
    );
    println!("cpu_features: {}", backend::cpu_features().join(","));
    let l2 = amq::kernels::cost::l2_bytes();
    println!("l2_kb: {}", l2 / 1024);
    // The batch-tile widths serving resolves at the two reference layer
    // shapes (hidden product, Harley–Seal regime) with 2-bit activations.
    for cols in [1024usize, 8192] {
        println!("tile_cols[{cols}c,a2]: {}", amq::kernels::binary::serving_tile_cols(cols, 2));
    }
    Ok(())
}

/// Query a running server's `STATS` endpoint (JSON by default, `--text`
/// for the human form) — machine-readable scraping for dashboards.
///
/// Every socket operation is bounded: a wedged or half-dead server makes
/// the probe fail fast instead of hanging a monitoring pipeline.
fn cmd_stats(cli: &Cli) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    use std::net::ToSocketAddrs;
    use std::time::Duration;
    let addr = cli.get_str("addr", "127.0.0.1:7860");
    let timeout = Duration::from_secs(5);
    let sock = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr}"))?
        .next()
        .with_context(|| format!("resolve {addr}: no addresses"))?;
    let mut conn = std::net::TcpStream::connect_timeout(&sock, timeout)
        .with_context(|| format!("connect {addr}"))?;
    conn.set_read_timeout(Some(timeout))?;
    conn.set_write_timeout(Some(timeout))?;
    writeln!(conn, "{}", if cli.has("text") { "STATS TEXT" } else { "STATS" })?;
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line)?;
    let line = line.trim_end();
    match line.strip_prefix("OK STATS ") {
        Some(payload) => {
            println!("{payload}");
            Ok(())
        }
        None => bail!("unexpected reply: {line}"),
    }
}

/// Quantize a model once and write the packed `.amqz` serving format: the
/// exact `PreparedGemm` plane/alpha layout, so `amq serve --model
/// name=file.amqz` maps it back with one bulk read and zero re-quantization
/// (see `data::amqz` for the layout and `rust/benches/model_registry.rs`
/// for the cold-load speedup this buys).
fn cmd_publish(cli: &Cli) -> Result<()> {
    let out = PathBuf::from(cli.get("out").context("--out <file.amqz> is required")?);
    let w_bits = cli.get_usize("w-bits", 2)?;
    let a_bits = cli.get_usize("a-bits", 2)?;
    if w_bits == 0 {
        bail!("publish needs a quantized model (--w-bits >= 1); .amqz stores packed bit-planes");
    }
    let kind = match cli.get_str("kind", "lstm").as_str() {
        "lstm" => RnnKind::Lstm,
        "gru" => RnnKind::Gru,
        other => bail!("unknown --kind '{other}' (lstm|gru)"),
    };
    let lm = LmConfig {
        kind,
        vocab: cli.get_usize("vocab", 2000)?,
        hidden: cli.get_usize("hidden", 200)?,
        layers: cli.get_usize("layers", 1)?,
    };
    let exec = Exec::new(ExecConfig::with_threads(cli.get_usize("threads", 0)?));
    let policy = PrecisionPolicy::quantized(w_bits, a_bits);
    let t0 = Instant::now();
    let model = match cli.get("checkpoint") {
        Some(p) => {
            let ckpt = amq::data::checkpoint::Checkpoint::load(std::path::Path::new(p))?;
            let w = amq::train::trainer::weights_from_checkpoint(&ckpt, &lm)?;
            RnnLm::from_weights_exec(lm, &w, policy, &exec)
        }
        None => {
            let seed = cli.get_usize("seed", 1)? as u64;
            eprintln!(
                "note: no --checkpoint — publishing a randomly initialized model (--seed {seed})"
            );
            RnnLm::random_exec(lm, seed, policy, &exec)
        }
    };
    let quantize_ms = t0.elapsed().as_secs_f64() * 1e3;
    let parts = model.to_packed()?;
    // `AMQ_FAULTS` (testing only) arms the publish path's torn-write /
    // bitflip / fsync seams — CI's chaos leg proves a mangled publish is
    // refused at load instead of served.
    let faults = amq::server::FaultPlan::from_env().map_err(anyhow::Error::msg)?;
    if faults.is_some() {
        eprintln!("warning: AMQ_FAULTS is set — publish fault injection is ACTIVE");
    }
    amqz::save_with_faults(&out, &parts, faults.as_deref())?;
    let file_bytes = std::fs::metadata(&out)?.len();
    println!(
        "published {} vocab={} hidden={} layers={} W{}A{} → {}: {} bytes on disk \
         ({} weight bytes in memory; built+quantized in {quantize_ms:.0} ms)",
        model.config.kind.name(),
        model.config.vocab,
        model.config.hidden,
        model.config.layers,
        w_bits,
        a_bits,
        out.display(),
        file_bytes,
        model.bytes(),
    );
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let tag = cli.get_str("tag", "lstm_fp");
    let spec = dataset(cli)?;
    let epochs = cli.get_usize("epochs", 4)?;
    let steps = cli.get_usize("steps", 150)?;
    let eval_steps = cli.get_usize("eval-steps", 40)?;
    let lr = cli.get_f64("lr", 20.0)?;
    eprintln!("generating corpus {} …", spec.name);
    let corpus = Corpus::generate(spec);
    eprintln!(
        "train {} on {} ({} tokens, unigram ppl {:.0})",
        tag,
        corpus.spec.name,
        corpus.train.len(),
        corpus.unigram_perplexity()
    );
    let dir = artifact_dir(cli);
    let mut trainer = amq::train::LmTrainer::load(&dir, &tag)
        .with_context(|| "loading artifacts (run `make artifacts`)")?;
    let schedule = amq::train::SgdSchedule::new(lr, 1.2, 1e-3, 80);
    let report = trainer.fit(
        &corpus.train,
        &corpus.valid,
        schedule,
        epochs,
        Some(steps),
        Some(eval_steps),
        |e, loss, val, lr| println!("epoch {e:>2}  train-nll {loss:.4}  val-ppw {val:.1}  lr {lr:.3}"),
    )?;
    let test = trainer.evaluate(&corpus.test, Some(eval_steps))?;
    println!(
        "done: {} steps, best val ppw {:.1}, test ppw {test:.1}",
        report.steps, report.best_val_ppw
    );
    let out = runs_dir(cli).join(format!("{tag}.amqt"));
    trainer.checkpoint().save(&out)?;
    println!("checkpoint saved to {}", out.display());
    Ok(())
}

fn cmd_quantize(cli: &Cli) -> Result<()> {
    let bits = cli.get_usize("bits", 2)?;
    // `--method alternating:3` style; `--cycles N` remains as an override.
    let mut method = cli.get_method("method", Method::Alternating { t: 2 })?;
    if let Method::Alternating { ref mut t } = method {
        *t = cli.get_usize("cycles", *t)?;
    }
    match cli.get("checkpoint") {
        Some(path) => {
            let ckpt = amq::data::checkpoint::Checkpoint::load(std::path::Path::new(path))?;
            println!("{:<14} {:>10} {:>12} {:>9}", "tensor", "shape", "rel-MSE", "saving");
            for (name, t) in &ckpt.tensors {
                if t.shape.len() != 2 {
                    continue;
                }
                let q = quant::RowQuantized::quantize(&t.data, t.shape[0], t.shape[1], bits, method);
                println!(
                    "{:<14} {:>4}x{:<5} {:>12.5} {:>8.1}x",
                    name,
                    t.shape[0],
                    t.shape[1],
                    q.relative_mse(&t.data),
                    q.compression()
                );
            }
        }
        None => {
            // Demo on a surrogate matrix.
            let mut rng = Rng::new(1);
            let w = rng.laplace_vec(1024 * 512, 0.1);
            let q = quant::RowQuantized::quantize(&w, 1024, 512, bits, method);
            println!(
                "{}-bit {} on laplace 1024x512: rel-MSE {:.5}, memory saving {:.1}x",
                bits,
                method,
                q.relative_mse(&w),
                q.compression()
            );
        }
    }
    Ok(())
}

fn cmd_bench(cli: &Cli) -> Result<()> {
    let which = cli.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let dir = artifact_dir(cli);
    let scale = cli.get_usize("scale", 8)?;
    match which {
        "table1" | "table2" => {
            let eval_tokens = cli.get_usize("eval-tokens", 3000)?;
            print!("{}", exp::quant_tables::run_default(scale, 5, eval_tokens, &runs_dir(cli)));
        }
        "table3" | "table4" | "table5" => {
            let t: usize = which[5..].parse().unwrap();
            let epochs = cli.get_usize("epochs", 3)?;
            let steps = cli.get_usize("steps", 60)?;
            let eval_steps = cli.get_usize("eval-steps", 20)?;
            let lr = cli.get_f64("lr", 20.0)?;
            let out = exp::table3_4_5(t, &dir, scale, epochs, steps, eval_steps, lr, |l| {
                eprintln!("{l}")
            })?;
            println!("{out}");
        }
        "table6" => {
            let full = cli.has("full");
            let shapes: &[(usize, usize)] =
                if full { &[(4096, 1024), (42000, 1024)] } else { &[(4096, 1024)] };
            let rows = exp::table6(shapes, cli.get_usize("samples", 15)?);
            print!("{}", exp::kernel_tables::render_table6(&rows));
            print!("{}", exp::costmodel(shapes, &rows));
        }
        "costmodel" => {
            let shapes = [(4096usize, 1024usize), (42000, 1024)];
            print!("{}", exp::costmodel(&shapes, &[]));
        }
        "table7" => {
            let rows = exp::table7(
                cli.get_usize("train-n", 800)?,
                cli.get_usize("test-n", 300)?,
                cli.get_usize("hidden", 64)?,
                cli.get_usize("epochs", 3)?,
            );
            print!("{}", exp::image_tables::render(7, &rows, "seq-MNIST-like, 1-bit in / 2-bit W / 2-bit A"));
        }
        "table8" => {
            let rows = exp::table8(
                cli.get_usize("train-n", 2000)?,
                cli.get_usize("test-n", 500)?,
                cli.get_usize("hidden", 256)?,
                cli.get_usize("epochs", 4)?,
            );
            print!("{}", exp::image_tables::render(8, &rows, "MNIST-like MLP, 2-bit in / 2-bit W / 1-bit A"));
        }
        "table9" => {
            let rows = exp::table9(
                cli.get_usize("train-n", 600)?,
                cli.get_usize("test-n", 200)?,
                cli.get_usize("base", 8)?,
                cli.get_usize("epochs", 2)?,
            );
            print!("{}", exp::image_tables::render(9, &rows, "CIFAR-like VGG (scaled), 2-bit W / 1-bit A"));
        }
        other => bail!("unknown bench '{other}' (table1..table9|costmodel)"),
    }
    Ok(())
}
