//! Serving/benchmark metrics: latency histograms, throughput counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::Summary;

/// Latency recorder (µs), thread-safe, exact percentiles.
#[derive(Default)]
pub struct LatencyRecorder {
    samples: Mutex<Summary>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.samples.lock().unwrap().add(d.as_secs_f64() * 1e6);
    }

    /// (count, mean_us, p50_us, p95_us, p99_us, max_us)
    pub fn snapshot(&self) -> LatencySnapshot {
        let mut s = self.samples.lock().unwrap();
        LatencySnapshot {
            count: s.len(),
            mean_us: s.mean(),
            p50_us: s.percentile(50.0),
            p95_us: s.percentile(95.0),
            p99_us: s.percentile(99.0),
            max_us: s.max(),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LatencySnapshot {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencySnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs max={:.1}µs",
            self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us, self.max_us
        )
    }
}

/// Monotonic event counters for the server.
#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub batches: AtomicU64,
    pub evictions: AtomicU64,
    pub errors: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_snapshot() {
        let r = LatencyRecorder::new();
        for ms in [1u64, 2, 3, 4, 100] {
            r.record(Duration::from_millis(ms));
        }
        let s = r.snapshot();
        assert_eq!(s.count, 5);
        assert!(s.p50_us >= 2000.0 && s.p50_us <= 4000.0);
        assert!(s.max_us >= 99_000.0);
    }

    #[test]
    fn counters() {
        let c = Counters::new();
        Counters::inc(&c.requests, 3);
        assert_eq!(Counters::get(&c.requests), 3);
    }
}
