//! Serving/benchmark metrics: latency histograms, throughput counters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::Summary;

/// Latency recorder (µs), thread-safe, exact percentiles.
#[derive(Default)]
pub struct LatencyRecorder {
    samples: Mutex<Summary>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.samples.lock().unwrap().add(d.as_secs_f64() * 1e6);
    }

    /// (count, mean_us, p50_us, p95_us, p99_us, max_us)
    pub fn snapshot(&self) -> LatencySnapshot {
        let mut s = self.samples.lock().unwrap();
        LatencySnapshot {
            count: s.len(),
            mean_us: s.mean(),
            p50_us: s.percentile(50.0),
            p95_us: s.percentile(95.0),
            p99_us: s.percentile(99.0),
            max_us: s.max(),
        }
    }
}

/// Latency recorder over a **fixed-size ring** of the most recent samples
/// (µs): bounded memory for servers that run forever, where the unbounded
/// [`LatencyRecorder`] would grow without limit. `count` in the snapshot is
/// the lifetime total; the percentiles describe the ring window (the last
/// `capacity` requests) — exactly what a `STATS` poll wants to see.
pub struct LatencyRing {
    inner: Mutex<RingInner>,
    capacity: usize,
}

struct RingInner {
    buf: Vec<f64>,
    next: usize,
    total: u64,
}

impl LatencyRing {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "ring capacity must be positive");
        LatencyRing {
            inner: Mutex::new(RingInner { buf: Vec::with_capacity(capacity), next: 0, total: 0 }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        let mut r = self.inner.lock().unwrap();
        if r.buf.len() < self.capacity {
            r.buf.push(us);
        } else {
            let i = r.next;
            r.buf[i] = us;
        }
        r.next = (r.next + 1) % self.capacity;
        r.total += 1;
    }

    /// Percentiles over the ring window; `count` is the lifetime total.
    pub fn snapshot(&self) -> LatencySnapshot {
        let r = self.inner.lock().unwrap();
        let mut s = Summary::new();
        for &v in &r.buf {
            s.add(v);
        }
        LatencySnapshot {
            count: r.total as usize,
            mean_us: s.mean(),
            p50_us: s.percentile(50.0),
            p95_us: s.percentile(95.0),
            p99_us: s.percentile(99.0),
            max_us: s.max(),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LatencySnapshot {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl LatencySnapshot {
    pub fn report(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs p99={:.1}µs max={:.1}µs",
            self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us, self.max_us
        )
    }
}

/// Monotonic event counters for the server.
#[derive(Default)]
pub struct Counters {
    pub requests: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub batches: AtomicU64,
    /// Batched decode timesteps executed (continuous batching progresses
    /// one of these at a time; joins and leaves happen at its boundary).
    pub decode_timesteps: AtomicU64,
    /// Generation requests refused with `ERR BUSY` because the pending
    /// queue was at `queue_depth` — the admission-control pressure valve.
    pub shed: AtomicU64,
    pub evictions: AtomicU64,
    pub errors: AtomicU64,
    /// Model-lane panics caught by the batcher's `catch_unwind` and
    /// quarantined (the lane dropped, its registry entry poisoned).
    pub lane_panics: AtomicU64,
    /// Requests answered `ERR DEADLINE` at a timestep boundary because
    /// they exceeded `--request-deadline-ms`.
    pub deadline_expirations: AtomicU64,
    /// Idle sessions dropped by the `--session-ttl-secs` sweep, exactly
    /// as if `END` had arrived for each.
    pub sessions_reaped: AtomicU64,
    /// Event-loop connections closed because their write buffer stayed
    /// unflushed past `--write-stall-ms` (slow-loris readers).
    pub write_stall_closes: AtomicU64,
    /// `DRAIN` requests (wire verb or SIGTERM) that completed a snapshot.
    pub drains: AtomicU64,
    /// Sessions serialized into drain snapshots.
    pub sessions_snapshotted: AtomicU64,
    /// Sessions revived from `--restore` snapshots at startup.
    pub sessions_restored: AtomicU64,
    /// Model loads refused because checksum verification failed
    /// (`ERR MODEL_CORRUPT`) — non-zero means a bad artifact is on disk.
    pub corrupt_loads_rejected: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_snapshot() {
        let r = LatencyRecorder::new();
        for ms in [1u64, 2, 3, 4, 100] {
            r.record(Duration::from_millis(ms));
        }
        let s = r.snapshot();
        assert_eq!(s.count, 5);
        assert!(s.p50_us >= 2000.0 && s.p50_us <= 4000.0);
        assert!(s.max_us >= 99_000.0);
    }

    #[test]
    fn counters() {
        let c = Counters::new();
        Counters::inc(&c.requests, 3);
        Counters::inc(&c.shed, 1);
        Counters::inc(&c.decode_timesteps, 2);
        assert_eq!(Counters::get(&c.requests), 3);
        assert_eq!(Counters::get(&c.shed), 1);
        assert_eq!(Counters::get(&c.decode_timesteps), 2);
    }

    #[test]
    fn latency_ring_windows_and_counts() {
        let r = LatencyRing::new(4);
        // Lifetime count keeps growing; the window holds the last 4.
        for ms in [100u64, 200, 300, 400, 1, 2, 3, 4] {
            r.record(Duration::from_millis(ms));
        }
        let s = r.snapshot();
        assert_eq!(s.count, 8);
        // Only the 1–4 ms tail is in the window now.
        assert!(s.max_us <= 5_000.0, "stale sample survived: {}", s.max_us);
        assert!(s.p50_us >= 1_000.0 && s.p50_us <= 4_000.0);
        // Partial window: percentiles over what is there.
        let r = LatencyRing::new(16);
        r.record(Duration::from_millis(7));
        let s = r.snapshot();
        assert_eq!(s.count, 1);
        assert!((s.p50_us - 7_000.0).abs() < 100.0);
    }
}
