//! # amq-rnn — Alternating Multi-bit Quantization for Recurrent Neural Networks
//!
//! A production-grade reproduction of *Xu et al., "Alternating Multi-bit
//! Quantization for Recurrent Neural Networks", ICLR 2018*, built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — `python/compile/kernels/`: the
//!   alternating quantization kernel (Algorithms 1 + 2 of the paper) and the
//!   quantized matmul, checked against a pure-`jnp` oracle.
//! * **Layer 2 (JAX, build time)** — `python/compile/model.py`: quantized
//!   LSTM/GRU language models trained with the straight-through estimator
//!   (the bi-level program of Eq. 7), AOT-lowered to HLO text artifacts.
//! * **Layer 3 (this crate, request path)** — native implementations of every
//!   quantization algorithm (Section 2 baselines + the paper's alternating
//!   method), the bit-packed XNOR/popcount kernels of Appendix A, the RNN
//!   inference stack, a serving coordinator (router + dynamic batcher +
//!   session cache), the training driver with the paper's SGD schedule, and
//!   the PJRT runtime that executes the Layer-2 artifacts.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! graphs once and the `amq` binary is self-contained afterwards.
//!
//! The inference API is **batch-first**: activations move through the model
//! as [`model::ActivationBatch`] (B vectors quantized once per batch into
//! shared bit-planes), every layer implements [`model::LinearOp`], and the
//! batched XNOR/popcount GEMM ([`kernels::binary::PreparedGemm`]) sweeps
//! each packed weight plane once per batch — the serving win of Fig. 3
//! (right). Single-vector entry points (`matvec`, `step`) remain as exact
//! `B = 1` paths for the trainer and simple callers.
//!
//! It is also **multi-threaded**: the [`exec`] engine (a std-only
//! persistent worker pool) row-shards every batched GEMM, the per-row
//! weight quantization, and the online activation quantization across CPU
//! cores, and runs the recurrent cells' two gate products as parallel
//! tasks. Sharding follows boundaries the serial code already treats
//! independently, so every `*_exec` path is **bit-exact** against its
//! serial counterpart for any thread count (`rust/tests/exec_parity.rs`) —
//! `threads = 1` *is* the serial path. The server exposes the knob as
//! `BatcherConfig::exec` / `amq serve --threads N` (default: all cores).
//!
//! ## Kernel backends
//!
//! Every XNOR/popcount count loop goes through **one fused batch-block
//! primitive** per backend ([`kernels::backend`]):
//! `block_counts(w, x_block, counts)` — one weight row's plane slices
//! against one batch block of column plane slices, accumulating the flat
//! `[column][w-plane][x-plane]` mismatch counts. The single-vector GEMV
//! is a one-column block; a plane pair is a 1×1×1 block. Backends:
//! portable scalar (`u64 ^` + `count_ones`, always available), AVX2
//! (`vpshufb` nibble-LUT popcount; on short planes a **fused block
//! kernel** with one byte-lane accumulator per chain — weight vectors
//! loaded once per word index, one reduction per chain per row — and
//! Harley–Seal carry-save pairwise passes on long planes, x86_64), and
//! NEON (`vcntq_u8` fused block kernel with widening folds, aarch64).
//! Selection order: explicit choice (`amq serve --kernel` /
//! `server.kernel` config) > `AMQ_KERNEL` env (`scalar|avx2|neon|auto`) >
//! feature detection (`is_x86_feature_detected!`).
//!
//! **Bit-exactness argument:** every output element reduces to exact
//! integer mismatch counts followed by a float reduction. Backends only
//! change how the counts are computed — the same integers in any
//! instruction mix, whether a chain is accumulated in `u8` SIMD lanes,
//! carry-save vectors, or a scalar register — and the float reduction is
//! one shared code path ([`kernels::binary`]), so every backend's f32
//! output is **bit-identical** to scalar's, across batch sizes and
//! thread counts (`rust/tests/kernel_parity.rs`, zero tolerance —
//! including partial batch blocks and asymmetric k_w ≠ k_x widths).
//! Switching backends is therefore a pure wall-time knob.
//!
//! **Adding a backend:** add a [`kernels::Kernel`] variant with an
//! `is_available` arm, implement **one function** —
//! `block_counts(w, x_block, counts)` — in a new arch-gated module, and
//! add one dispatch arm in `kernels::backend`. The cross-backend parity
//! suite and the bench sweeps pick new backends up automatically via
//! `Kernel::available()`.
//!
//! ## Zero-allocation serving workspaces
//!
//! The steady-state decode path allocates **nothing**. Every layer of the
//! step has an `_into` variant that writes into caller-owned buffers which
//! are resized in place (capacity kept): the fused quantizers
//! (`quant::{greedy, lsq, bst, alternating}::*_into` over packed words +
//! a per-task [`quant::QuantScratch`]),
//! [`quant::QuantizedBatch::quantize_into_exec`] (reused plane/alpha
//! buffers), [`kernels::binary::PreparedGemm::gemm_into`],
//! [`model::LinearOp::forward_into_exec`] with a
//! [`model::LinearWorkspace`], the cell steps
//! (`LstmCell::step_batch_into_exec`, `GruCell::step_batch_into_exec`)
//! with **double-buffered** state — the next state is computed into a
//! spare buffer that must not alias the current one, then the two are
//! swapped — and `RnnLm::step_batch_into_exec` threading one
//! [`model::LmStepWorkspace`] through the whole timestep. The server's
//! batcher holds these workspaces per process and reuses them across every
//! prime + decode timestep group.
//!
//! The allocating APIs (`step_batch_exec`, `forward_exec`,
//! `QuantizedBatch::quantize_with_exec`, …) are thin wrappers that run the
//! same `_into` core with fresh buffers — **one code path**, so buffer
//! reuse is bit-exact by construction. Use the wrappers for one-shot calls
//! (trainers, evals, tests); use the `_into` APIs wherever a loop runs
//! more than a handful of steps. Guarantees: after one warm-up call at the
//! high-water shape, a steady-state `step_batch_into_exec` timestep
//! performs zero heap allocations on the serial engine (pinned by a
//! counting global allocator in `rust/tests/workspace_parity.rs`; the
//! worker pool adds only its per-scope task boxes, and `k ≥ 5` code sorts
//! may spill — neither is on the serving path).
//!
//! ## Quick tour
//!
//! ```
//! use amq::quant::alternating;
//!
//! let w: Vec<f32> = (0..256).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
//! // 2-bit alternating quantization, T = 2 cycles (the paper's setting).
//! let q = alternating::quantize(&w, 2, 2);
//! let err = amq::quant::relative_mse(&w, &q.dequantize());
//! assert!(err < 0.2); // Table 1 reports ~0.125 on trained LSTM weights
//! ```
//!
//! Batched quantized inference — the serving hot path:
//!
//! ```
//! use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
//!
//! let lm = RnnLm::random(
//!     LmConfig { kind: RnnKind::Lstm, vocab: 64, hidden: 32, layers: 1 },
//!     7,
//!     PrecisionPolicy::quantized(2, 2),
//! );
//! // Four sessions advance one token each in ONE pass over the weights.
//! let mut state = lm.zero_state_batch(4);
//! let logits = lm.step_batch(&[1, 9, 17, 33], &mut state);
//! assert_eq!(logits.batch(), 4);
//! assert_eq!(logits.dim(), 64);
//! // Bit-exact vs the per-session path:
//! let mut s1 = lm.zero_state();
//! assert_eq!(logits.row(0), &lm.step(1, &mut s1)[..]);
//! ```

pub mod cli;
pub mod config;
pub mod data;
pub mod exec;
pub mod exp;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod train;
pub mod util;

pub use quant::Quantized;
