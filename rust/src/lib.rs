//! # amq-rnn — Alternating Multi-bit Quantization for Recurrent Neural Networks
//!
//! A production-grade reproduction of *Xu et al., "Alternating Multi-bit
//! Quantization for Recurrent Neural Networks", ICLR 2018*, built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — `python/compile/kernels/`: the
//!   alternating quantization kernel (Algorithms 1 + 2 of the paper) and the
//!   quantized matmul, checked against a pure-`jnp` oracle.
//! * **Layer 2 (JAX, build time)** — `python/compile/model.py`: quantized
//!   LSTM/GRU language models trained with the straight-through estimator
//!   (the bi-level program of Eq. 7), AOT-lowered to HLO text artifacts.
//! * **Layer 3 (this crate, request path)** — native implementations of every
//!   quantization algorithm (Section 2 baselines + the paper's alternating
//!   method), the bit-packed XNOR/popcount kernels of Appendix A, the RNN
//!   inference stack, a serving coordinator (router + dynamic batcher +
//!   session cache), the training driver with the paper's SGD schedule, and
//!   the PJRT runtime that executes the Layer-2 artifacts.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! graphs once and the `amq` binary is self-contained afterwards.
//!
//! ## Quick tour
//!
//! ```
//! use amq::quant::alternating;
//!
//! let w: Vec<f32> = (0..256).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
//! // 2-bit alternating quantization, T = 2 cycles (the paper's setting).
//! let q = alternating::quantize(&w, 2, 2);
//! let err = amq::quant::relative_mse(&w, &q.dequantize());
//! assert!(err < 0.2); // Table 1 reports ~0.125 on trained LSTM weights
//! ```

pub mod cli;
pub mod config;
pub mod data;
pub mod exp;
pub mod kernels;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod train;
pub mod util;

pub use quant::Quantized;
