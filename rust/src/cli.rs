//! Minimal CLI argument parser (the vendored crate set has no `clap`):
//! subcommand + `--key value` / `--flag` options with typed accessors.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::kernels::Kernel;
use crate::quant::Method;

/// Parsed command line: `amq <subcommand> [--key value]...`.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub subcommand: String,
    /// Last occurrence wins (the single-value accessors below).
    pub options: BTreeMap<String, String>,
    /// Every `--key value` occurrence in argv order — for repeatable flags
    /// like `serve --model a=a.amqz --model b=b.amqz` (see [`Self::get_all`]).
    pub repeated: Vec<(String, String)>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut it = args.into_iter();
        let subcommand = it.next().unwrap_or_default();
        let mut options = BTreeMap::new();
        let mut repeated = Vec::new();
        let mut positional = Vec::new();
        let mut pending: Option<String> = None;
        for a in it {
            if let Some(key) = a.strip_prefix("--") {
                if let Some(prev) = pending.take() {
                    repeated.push((prev.clone(), "true".to_string()));
                    options.insert(prev, "true".into()); // bare flag
                }
                pending = Some(key.to_string());
            } else if let Some(key) = pending.take() {
                repeated.push((key.clone(), a.clone()));
                options.insert(key, a);
            } else {
                positional.push(a);
            }
        }
        if let Some(prev) = pending.take() {
            repeated.push((prev.clone(), "true".to_string()));
            options.insert(prev, "true".into());
        }
        if subcommand.starts_with("--") {
            bail!("expected a subcommand before options");
        }
        Ok(Cli { subcommand, options, repeated, positional })
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Parse a quantization-method flag via [`Method`]'s `FromStr`
    /// (`uniform|balanced|greedy|refined|alternating[:cycles]|ternary`) —
    /// the one consistent spelling for every ablation surface.
    pub fn get_method(&self, key: &str, default: Method) -> Result<Method> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    /// Parse a kernel-backend selection flag (`scalar|avx2|avx512|neon|auto`).
    /// `None` means "no explicit choice" (flag absent or `auto`) — the
    /// caller falls through to `AMQ_KERNEL` / runtime detection. Naming a
    /// backend this host cannot run is an error, never a silent fallback.
    pub fn get_kernel(&self, key: &str) -> Result<Option<Kernel>> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => Kernel::parse_choice(v).map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    /// Every value given for a repeatable `--key`, in argv order (the
    /// `BTreeMap` keeps only the last).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.repeated.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let c = Cli::parse(args("serve --addr 0.0.0.0:1234 --quantized --max-batch 8 pos1")).unwrap();
        assert_eq!(c.subcommand, "serve");
        assert_eq!(c.get("addr"), Some("0.0.0.0:1234"));
        assert!(c.has("quantized"));
        assert_eq!(c.get_usize("max-batch", 0).unwrap(), 8);
        assert_eq!(c.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_errors() {
        let c = Cli::parse(args("bench --steps abc")).unwrap();
        assert!(c.get_usize("steps", 1).is_err());
        assert!(Cli::parse(args("--oops first")).is_err());
    }

    #[test]
    fn defaults() {
        let c = Cli::parse(args("bench")).unwrap();
        assert_eq!(c.get_usize("steps", 42).unwrap(), 42);
        assert_eq!(c.get_str("out", "x"), "x");
    }

    #[test]
    fn method_flag() {
        let c = Cli::parse(args("quantize --method refined")).unwrap();
        assert_eq!(c.get_method("method", Method::Ternary).unwrap(), Method::Refined);
        let c = Cli::parse(args("quantize --method alternating:4")).unwrap();
        assert_eq!(
            c.get_method("method", Method::Ternary).unwrap(),
            Method::Alternating { t: 4 }
        );
        let c = Cli::parse(args("quantize")).unwrap();
        assert_eq!(c.get_method("method", Method::Greedy).unwrap(), Method::Greedy);
        assert!(Cli::parse(args("quantize --method wat"))
            .unwrap()
            .get_method("method", Method::Greedy)
            .is_err());
    }

    #[test]
    fn kernel_flag() {
        let c = Cli::parse(args("serve")).unwrap();
        assert_eq!(c.get_kernel("kernel").unwrap(), None);
        let c = Cli::parse(args("serve --kernel auto")).unwrap();
        assert_eq!(c.get_kernel("kernel").unwrap(), None);
        let c = Cli::parse(args("serve --kernel scalar")).unwrap();
        assert_eq!(c.get_kernel("kernel").unwrap(), Some(Kernel::Scalar));
        let c = Cli::parse(args("serve --kernel wat")).unwrap();
        assert!(c.get_kernel("kernel").is_err());
    }

    #[test]
    fn trailing_flag() {
        let c = Cli::parse(args("serve --verbose")).unwrap();
        assert!(c.has("verbose"));
    }

    #[test]
    fn repeated_flags_keep_every_occurrence() {
        let c = Cli::parse(args("serve --model a=a.amqz --addr :0 --model b=b.amqz")).unwrap();
        assert_eq!(c.get_all("model"), vec!["a=a.amqz", "b=b.amqz"]);
        // The map keeps the last for single-value accessors.
        assert_eq!(c.get("model"), Some("b=b.amqz"));
        assert_eq!(c.get_all("addr"), vec![":0"]);
        assert!(c.get_all("missing").is_empty());
    }
}
