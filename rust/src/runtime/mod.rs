//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see DESIGN.md; serialized protos
//! from jax ≥ 0.5 are rejected by xla_extension 0.5.1) and executes them on
//! the CPU PJRT client from the Rust hot path.
//!
//! One [`Engine`] holds the client plus every compiled executable, keyed by
//! artifact name (`train_step`, `eval_step`, …). Python never runs here.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Host-side tensor (f32, row-major) crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar.
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        // Convert non-f32 outputs (e.g. s32 argmax) to f32 for a uniform API.
        let lit_f32 = if lit.ty()? == xla::ElementType::F32 {
            lit.to_vec::<f32>()?
        } else {
            lit.convert(xla::PrimitiveType::F32)?.to_vec::<f32>()?
        };
        Ok(HostTensor { shape: dims, data: lit_f32 })
    }
}

/// Integer token tensor (lowered as i32 on the XLA side).
#[derive(Clone, Debug)]
pub struct HostTokens {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl HostTokens {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTokens { shape, data }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// An argument to an artifact execution.
pub enum Arg<'a> {
    F32(&'a HostTensor),
    I32(&'a HostTokens),
}

/// The PJRT engine: CPU client + compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    artifact_dir: PathBuf,
}

impl Engine {
    /// Create a CPU engine rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client, executables: HashMap::new(), artifact_dir: artifact_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<artifact_dir>/<name>.hlo.txt` under key `name`.
    pub fn load(&mut self, name: &str) -> Result<()> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        self.load_path(name, &path)
    }

    /// Load + compile an explicit HLO text file under `name`.
    pub fn load_path(&mut self, name: &str, path: &Path) -> Result<()> {
        if !path.exists() {
            bail!(
                "artifact '{}' not found at {} — run `make artifacts` first",
                name,
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact '{name}'"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute artifact `name`. All our artifacts are lowered with
    /// `return_tuple=True`, so the single output is a tuple that we flatten
    /// into `HostTensor`s.
    pub fn execute(&self, name: &str, args: &[Arg<'_>]) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded (have: {:?})", self.names()))?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| match a {
                Arg::F32(t) => t.to_literal(),
                Arg::I32(t) => t.to_literal(),
            })
            .collect::<Result<_>>()?;
        let out = exe.execute::<xla::Literal>(&literals)?;
        let result = out[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.shape, vec![2, 2]);
        let s = HostTensor::scalar(5.0);
        assert!(s.shape.is_empty());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn host_tensor_mismatch_panics() {
        HostTensor::new(vec![3], vec![1.0]);
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let mut e = Engine::cpu("/nonexistent_dir").unwrap();
        let err = e.load("nope").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    // Round-trip execution is covered by the integration test
    // `rust/tests/runtime_roundtrip.rs`, which requires `make artifacts`.
}
