//! Batched multi-bit activation codes.
//!
//! A [`QuantizedBatch`] holds the quantization of `B` activation vectors in
//! one contiguous buffer — the activation-side operand of the batched
//! XNOR/popcount GEMM (`kernels::binary::PreparedGemm`). Each vector keeps
//! its own `k` coefficients (quantization is per-vector, exactly as in
//! [`Quantized`]), but the bit planes are packed back-to-back so a batch's
//! entire working set streams sequentially while the weight planes are
//! walked **once per batch** instead of once per vector (Fig. 3 right).
//!
//! Layout:
//!
//! ```text
//! data:   [b][s][word]   — column b, plane s, ⌈n/64⌉ words per plane
//! alphas: [b][s]         — α_s of column b
//! ```

use super::{quantize, Method, PackedBits, Quantized};
use crate::exec::{Exec, SendPtr};

/// `B` activation vectors of dimension `n`, each quantized to `k` bits,
/// packed into shared contiguous plane storage.
#[derive(Clone, Debug)]
pub struct QuantizedBatch {
    /// Number of vectors `B`.
    pub batch: usize,
    /// Dimension of each vector.
    pub n: usize,
    /// Bits per vector.
    pub k: usize,
    /// Words per bit plane, `⌈n/64⌉`.
    pub words_per_plane: usize,
    /// Packed planes, `batch · k · words_per_plane` words, layout `[b][s][word]`.
    pub data: Vec<u64>,
    /// Coefficients, `batch · k`, layout `[b][s]`.
    pub alphas: Vec<f32>,
}

impl QuantizedBatch {
    /// Quantize `batch` row-major vectors with the paper's online setting
    /// (alternating, `T = 2`) — identical per-row output to
    /// `kernels::binary::quantize_activations`.
    pub fn quantize(x: &[f32], batch: usize, n: usize, k: usize) -> Self {
        Self::quantize_with(x, batch, n, k, Method::Alternating { t: 2 })
    }

    /// [`Self::quantize`] on an execution engine: rows are quantized
    /// independently, so they shard across workers with bit-identical
    /// output for any thread count.
    pub fn quantize_exec(x: &[f32], batch: usize, n: usize, k: usize, exec: &Exec) -> Self {
        Self::quantize_with_exec(x, batch, n, k, Method::Alternating { t: 2 }, exec)
    }

    /// Quantize with an arbitrary method (ablations).
    pub fn quantize_with(x: &[f32], batch: usize, n: usize, k: usize, method: Method) -> Self {
        Self::quantize_with_exec(x, batch, n, k, method, &Exec::serial())
    }

    /// Method + engine variant. Each row `b` writes only its own
    /// `data[b·k·wpp ..]` / `alphas[b·k ..]` ranges — disjoint per row, so
    /// row sharding is race-free and bit-exact by construction.
    pub fn quantize_with_exec(
        x: &[f32],
        batch: usize,
        n: usize,
        k: usize,
        method: Method,
        exec: &Exec,
    ) -> Self {
        assert_eq!(x.len(), batch * n, "batch shape mismatch");
        // Ternary always emits two planes regardless of `k` (see RowQuantized).
        let kk = if matches!(method, Method::Ternary) { 2 } else { k };
        let wpp = n.div_ceil(64);
        let mut data = vec![0u64; batch * kk * wpp];
        let mut alphas = vec![0.0f32; batch * kk];
        let dptr = SendPtr::new(&mut data);
        let aptr = SendPtr::new(&mut alphas);
        let (dptr, aptr) = (&dptr, &aptr);
        exec.run_chunks(batch, 1, &|b0, b1| {
            for b in b0..b1 {
                let q = quantize(&x[b * n..(b + 1) * n], k, method);
                debug_assert_eq!(q.k(), kk);
                // SAFETY: row b's coefficient and plane ranges are written
                // by exactly this task (rows are disjoint across chunks).
                let arow = unsafe { aptr.slice_mut(b * kk, kk) };
                arow.copy_from_slice(&q.alphas);
                for (s, plane) in q.planes.iter().enumerate() {
                    let drow = unsafe { dptr.slice_mut((b * kk + s) * wpp, wpp) };
                    drow.copy_from_slice(plane.words());
                }
            }
        });
        QuantizedBatch { batch, n, k: kk, words_per_plane: wpp, data, alphas }
    }

    /// Pack already-quantized vectors (e.g. embedding rows looked up for a
    /// token batch). All rows must share `n` and `k`.
    pub fn from_rows(rows: &[Quantized]) -> Self {
        assert!(!rows.is_empty(), "empty batch");
        let n = rows[0].n;
        let k = rows[0].k();
        let wpp = n.div_ceil(64);
        let mut data = Vec::with_capacity(rows.len() * k * wpp);
        let mut alphas = Vec::with_capacity(rows.len() * k);
        for q in rows {
            assert_eq!(q.n, n, "row dimension mismatch");
            assert_eq!(q.k(), k, "row bit-width mismatch");
            alphas.extend_from_slice(&q.alphas);
            for plane in &q.planes {
                data.extend_from_slice(plane.words());
            }
        }
        QuantizedBatch { batch: rows.len(), n, k, words_per_plane: wpp, data, alphas }
    }

    /// Gather rows of a row-quantized matrix (e.g. embedding rows for a
    /// token batch) straight into the contiguous batch layout — one copy,
    /// no intermediate [`Quantized`] allocations. Bit-identical to
    /// `from_rows(&ids.map(|id| w.row(id)))`.
    pub fn gather_rows(w: &super::RowQuantized, ids: &[usize]) -> Self {
        assert!(!ids.is_empty(), "empty batch");
        let (n, k) = (w.cols, w.k);
        let wpp = n.div_ceil(64);
        let mut data = Vec::with_capacity(ids.len() * k * wpp);
        let mut alphas = Vec::with_capacity(ids.len() * k);
        for &id in ids {
            assert!(id < w.rows, "row {id} out of bounds ({} rows)", w.rows);
            alphas.extend_from_slice(&w.alphas[id * k..(id + 1) * k]);
            for s in 0..k {
                data.extend_from_slice(w.planes[id * k + s].words());
            }
        }
        QuantizedBatch { batch: ids.len(), n, k, words_per_plane: wpp, data, alphas }
    }

    /// The words of plane `s` of column `b`.
    #[inline]
    pub fn plane_words(&self, b: usize, s: usize) -> &[u64] {
        let w = self.words_per_plane;
        let base = (b * self.k + s) * w;
        &self.data[base..base + w]
    }

    /// Coefficient `α_s` of column `b`.
    #[inline]
    pub fn alpha(&self, b: usize, s: usize) -> f32 {
        self.alphas[b * self.k + s]
    }

    /// Column `b` as a standalone [`Quantized`] (bit-identical round-trip).
    pub fn column(&self, b: usize) -> Quantized {
        assert!(b < self.batch, "column {b} out of batch {}", self.batch);
        Quantized {
            n: self.n,
            alphas: self.alphas[b * self.k..(b + 1) * self.k].to_vec(),
            planes: (0..self.k)
                .map(|s| PackedBits::from_words(self.n, self.plane_words(b, s).to_vec()))
                .collect(),
        }
    }

    /// Dense reconstruction of the whole batch, row-major `batch × n`.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.batch * self.n);
        for b in 0..self.batch {
            out.extend(self.column(b).dequantize());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quantize_matches_per_vector() {
        let mut rng = Rng::new(55);
        let (batch, n, k) = (5, 70, 2);
        let x = rng.normal_vec(batch * n, 1.0);
        let qb = QuantizedBatch::quantize(&x, batch, n, k);
        for b in 0..batch {
            let q = quantize(&x[b * n..(b + 1) * n], k, Method::Alternating { t: 2 });
            let col = qb.column(b);
            assert_eq!(col.alphas, q.alphas, "column {b}");
            assert_eq!(col.planes, q.planes, "column {b}");
        }
    }

    #[test]
    fn from_rows_roundtrip() {
        let mut rng = Rng::new(56);
        let rows: Vec<Quantized> = (0..4)
            .map(|_| quantize(&rng.normal_vec(33, 0.5), 3, Method::Greedy))
            .collect();
        let qb = QuantizedBatch::from_rows(&rows);
        assert_eq!(qb.batch, 4);
        assert_eq!(qb.k, 3);
        for (b, q) in rows.iter().enumerate() {
            assert_eq!(qb.column(b).dequantize(), q.dequantize());
        }
    }

    #[test]
    fn gather_rows_matches_from_rows() {
        let mut rng = Rng::new(58);
        let (rows, cols, k) = (9, 70, 2);
        let w = crate::quant::RowQuantized::quantize(
            &rng.normal_vec(rows * cols, 0.4),
            rows,
            cols,
            k,
            Method::Alternating { t: 2 },
        );
        let ids = [4usize, 0, 8, 4];
        let fast = QuantizedBatch::gather_rows(&w, &ids);
        let slow = QuantizedBatch::from_rows(&ids.iter().map(|&id| w.row(id)).collect::<Vec<_>>());
        assert_eq!(fast.batch, slow.batch);
        assert_eq!(fast.alphas, slow.alphas);
        assert_eq!(fast.data, slow.data);
    }

    #[test]
    fn dequantize_is_columnwise() {
        let mut rng = Rng::new(57);
        let (batch, n) = (3, 40);
        let x = rng.normal_vec(batch * n, 0.7);
        let qb = QuantizedBatch::quantize(&x, batch, n, 2);
        let d = qb.dequantize();
        for b in 0..batch {
            assert_eq!(&d[b * n..(b + 1) * n], &qb.column(b).dequantize()[..]);
        }
    }

    #[test]
    #[should_panic(expected = "batch shape mismatch")]
    fn shape_mismatch_panics() {
        QuantizedBatch::quantize(&[0.0; 10], 3, 4, 2);
    }
}
