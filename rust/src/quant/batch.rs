//! Batched multi-bit activation codes.
//!
//! A [`QuantizedBatch`] holds the quantization of `B` activation vectors in
//! one contiguous buffer — the activation-side operand of the batched
//! XNOR/popcount GEMM (`kernels::binary::PreparedGemm`). Each vector keeps
//! its own `k` coefficients (quantization is per-vector, exactly as in
//! [`Quantized`]), but the bit planes are packed back-to-back so a batch's
//! entire working set streams sequentially while the weight planes are
//! walked **once per batch** instead of once per vector (Fig. 3 right).
//!
//! Layout:
//!
//! ```text
//! data:   [b][s][word]   — column b, plane s, ⌈n/64⌉ words per plane
//! alphas: [b][s]         — α_s of column b
//! ```

use super::{quantize_row_into, Method, PackedBits, QuantScratch, Quantized};
use crate::exec::{Exec, SendPtr};

/// `B` activation vectors of dimension `n`, each quantized to `k` bits,
/// packed into shared contiguous plane storage.
#[derive(Clone, Debug)]
pub struct QuantizedBatch {
    /// Number of vectors `B`.
    pub batch: usize,
    /// Dimension of each vector.
    pub n: usize,
    /// Bits per vector.
    pub k: usize,
    /// Words per bit plane, `⌈n/64⌉`.
    pub words_per_plane: usize,
    /// Packed planes, `batch · k · words_per_plane` words, layout `[b][s][word]`.
    pub data: Vec<u64>,
    /// Coefficients, `batch · k`, layout `[b][s]`.
    pub alphas: Vec<f32>,
}

impl QuantizedBatch {
    /// Quantize `batch` row-major vectors with the paper's online setting
    /// (alternating, `T = 2`) — identical per-row output to
    /// `kernels::binary::quantize_activations`.
    pub fn quantize(x: &[f32], batch: usize, n: usize, k: usize) -> Self {
        Self::quantize_with(x, batch, n, k, Method::Alternating { t: 2 })
    }

    /// [`Self::quantize`] on an execution engine: rows are quantized
    /// independently, so they shard across workers with bit-identical
    /// output for any thread count.
    pub fn quantize_exec(x: &[f32], batch: usize, n: usize, k: usize, exec: &Exec) -> Self {
        Self::quantize_with_exec(x, batch, n, k, Method::Alternating { t: 2 }, exec)
    }

    /// Quantize with an arbitrary method (ablations).
    pub fn quantize_with(x: &[f32], batch: usize, n: usize, k: usize, method: Method) -> Self {
        Self::quantize_with_exec(x, batch, n, k, method, &Exec::serial())
    }

    /// Method + engine variant — a thin wrapper over
    /// [`Self::quantize_into_exec`] with fresh buffers (one code path).
    pub fn quantize_with_exec(
        x: &[f32],
        batch: usize,
        n: usize,
        k: usize,
        method: Method,
        exec: &Exec,
    ) -> Self {
        let mut out = QuantizedBatch::empty();
        let mut scratches: Vec<QuantScratch> = Vec::new();
        scratches.resize_with(exec.threads().min(batch).max(1), QuantScratch::default);
        out.quantize_into_exec(x, batch, n, k, method, exec, &mut scratches);
        out
    }

    /// An empty batch — the starting point for the `_into` buffer-reuse
    /// APIs ([`Self::quantize_into_exec`], [`Self::gather_rows_into`]).
    pub fn empty() -> Self {
        QuantizedBatch {
            batch: 0,
            n: 0,
            k: 0,
            words_per_plane: 0,
            data: Vec::new(),
            alphas: Vec::new(),
        }
    }

    /// Quantize a row-major `batch × n` activation matrix into this batch's
    /// existing `data`/`alphas` buffers, resizing in place (capacity is
    /// kept, so a steady-state serving loop re-quantizes every timestep
    /// with **zero heap allocations** once the buffers and `scratches` are
    /// warm). Each row `b` writes only its own `data[b·k·wpp ..]` /
    /// `alphas[b·k ..]` ranges — disjoint per row, so row sharding is
    /// race-free and bit-exact by construction; each worker task uses its
    /// own scratch slot (`scratches.len()` must cover the task count, at
    /// most `exec.threads()`). Bit-identical to [`Self::quantize_with_exec`]
    /// for every method and thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn quantize_into_exec(
        &mut self,
        x: &[f32],
        batch: usize,
        n: usize,
        k: usize,
        method: Method,
        exec: &Exec,
        scratches: &mut [QuantScratch],
    ) {
        assert_eq!(x.len(), batch * n, "batch shape mismatch");
        // Ternary always emits two planes regardless of `k` (see RowQuantized).
        let kk = if matches!(method, Method::Ternary) { 2 } else { k };
        let wpp = n.div_ceil(64);
        let tasks = exec.threads().min(batch).max(1);
        assert!(scratches.len() >= tasks, "need one QuantScratch per worker task ({tasks})");
        self.batch = batch;
        self.n = n;
        self.k = kk;
        self.words_per_plane = wpp;
        self.data.clear();
        self.data.resize(batch * kk * wpp, 0);
        self.alphas.clear();
        self.alphas.resize(batch * kk, 0.0);
        let dptr = SendPtr::new(&mut self.data);
        let aptr = SendPtr::new(&mut self.alphas);
        let sptr = SendPtr::new(scratches);
        let (dptr, aptr, sptr) = (&dptr, &aptr, &sptr);
        exec.run_chunks_indexed(batch, 1, &|task, b0, b1| {
            // SAFETY: each task owns scratch slot `task` exclusively (task
            // indices are distinct and below the asserted scratch count).
            let scratch = unsafe { &mut sptr.slice_mut(task, 1)[0] };
            for b in b0..b1 {
                // SAFETY: row b's coefficient and plane ranges are written
                // by exactly this task (rows are disjoint across chunks).
                let arow = unsafe { aptr.slice_mut(b * kk, kk) };
                let drow = unsafe { dptr.slice_mut(b * kk * wpp, kk * wpp) };
                quantize_row_into(&x[b * n..(b + 1) * n], k, method, arow, drow, scratch);
            }
        });
    }

    /// Pack already-quantized vectors (e.g. embedding rows looked up for a
    /// token batch). All rows must share `n` and `k`.
    pub fn from_rows(rows: &[Quantized]) -> Self {
        assert!(!rows.is_empty(), "empty batch");
        let n = rows[0].n;
        let k = rows[0].k();
        let wpp = n.div_ceil(64);
        let mut data = Vec::with_capacity(rows.len() * k * wpp);
        let mut alphas = Vec::with_capacity(rows.len() * k);
        for q in rows {
            assert_eq!(q.n, n, "row dimension mismatch");
            assert_eq!(q.k(), k, "row bit-width mismatch");
            alphas.extend_from_slice(&q.alphas);
            for plane in &q.planes {
                data.extend_from_slice(plane.words());
            }
        }
        QuantizedBatch { batch: rows.len(), n, k, words_per_plane: wpp, data, alphas }
    }

    /// Gather rows of a row-quantized matrix (e.g. embedding rows for a
    /// token batch) straight into the contiguous batch layout — one copy,
    /// no intermediate [`Quantized`] allocations. Bit-identical to
    /// `from_rows(&ids.map(|id| w.row(id)))`.
    pub fn gather_rows(w: &super::RowQuantized, ids: &[usize]) -> Self {
        let mut out = QuantizedBatch::empty();
        out.gather_rows_into(w, ids);
        out
    }

    /// [`Self::gather_rows`] into this batch's existing buffers (capacity
    /// kept — a steady-state decode loop gathers every timestep's embedding
    /// rows with zero heap allocations).
    pub fn gather_rows_into(&mut self, w: &super::RowQuantized, ids: &[usize]) {
        assert!(!ids.is_empty(), "empty batch");
        let (n, k) = (w.cols, w.k);
        self.batch = ids.len();
        self.n = n;
        self.k = k;
        self.words_per_plane = n.div_ceil(64);
        self.data.clear();
        self.alphas.clear();
        for &id in ids {
            assert!(id < w.rows, "row {id} out of bounds ({} rows)", w.rows);
            self.alphas.extend_from_slice(&w.alphas[id * k..(id + 1) * k]);
            for s in 0..k {
                self.data.extend_from_slice(w.planes[id * k + s].words());
            }
        }
    }

    /// The words of plane `s` of column `b`.
    #[inline]
    pub fn plane_words(&self, b: usize, s: usize) -> &[u64] {
        let w = self.words_per_plane;
        let base = (b * self.k + s) * w;
        &self.data[base..base + w]
    }

    /// Coefficient `α_s` of column `b`.
    #[inline]
    pub fn alpha(&self, b: usize, s: usize) -> f32 {
        self.alphas[b * self.k + s]
    }

    /// Column `b` as a standalone [`Quantized`] (bit-identical round-trip).
    pub fn column(&self, b: usize) -> Quantized {
        assert!(b < self.batch, "column {b} out of batch {}", self.batch);
        Quantized {
            n: self.n,
            alphas: self.alphas[b * self.k..(b + 1) * self.k].to_vec(),
            planes: (0..self.k)
                .map(|s| PackedBits::from_words(self.n, self.plane_words(b, s).to_vec()))
                .collect(),
        }
    }

    /// Dense reconstruction of the whole batch, row-major `batch × n`.
    ///
    /// Word-wise direct expansion over the packed batch buffer (one shift
    /// per element), plane-major per column in ascending element order —
    /// the same accumulation order as `column(b).dequantize()`, so the
    /// output is bit-identical to the old clone-every-column path without
    /// materializing any intermediate `Quantized`.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.batch * self.n];
        for b in 0..self.batch {
            let o = &mut out[b * self.n..(b + 1) * self.n];
            for s in 0..self.k {
                let alpha = self.alpha(b, s);
                for (wi, &word) in self.plane_words(b, s).iter().enumerate() {
                    let base = wi * 64;
                    let live = 64.min(self.n - base);
                    let mut bits = word;
                    for v in o[base..base + live].iter_mut() {
                        *v += if bits & 1 == 1 { alpha } else { -alpha };
                        bits >>= 1;
                    }
                }
            }
        }
        out
    }
}

impl Default for QuantizedBatch {
    fn default() -> Self {
        Self::empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize;
    use crate::util::Rng;

    #[test]
    fn quantize_matches_per_vector() {
        let mut rng = Rng::new(55);
        let (batch, n, k) = (5, 70, 2);
        let x = rng.normal_vec(batch * n, 1.0);
        let qb = QuantizedBatch::quantize(&x, batch, n, k);
        for b in 0..batch {
            let q = quantize(&x[b * n..(b + 1) * n], k, Method::Alternating { t: 2 });
            let col = qb.column(b);
            assert_eq!(col.alphas, q.alphas, "column {b}");
            assert_eq!(col.planes, q.planes, "column {b}");
        }
    }

    #[test]
    fn from_rows_roundtrip() {
        let mut rng = Rng::new(56);
        let rows: Vec<Quantized> = (0..4)
            .map(|_| quantize(&rng.normal_vec(33, 0.5), 3, Method::Greedy))
            .collect();
        let qb = QuantizedBatch::from_rows(&rows);
        assert_eq!(qb.batch, 4);
        assert_eq!(qb.k, 3);
        for (b, q) in rows.iter().enumerate() {
            assert_eq!(qb.column(b).dequantize(), q.dequantize());
        }
    }

    #[test]
    fn gather_rows_matches_from_rows() {
        let mut rng = Rng::new(58);
        let (rows, cols, k) = (9, 70, 2);
        let w = crate::quant::RowQuantized::quantize(
            &rng.normal_vec(rows * cols, 0.4),
            rows,
            cols,
            k,
            Method::Alternating { t: 2 },
        );
        let ids = [4usize, 0, 8, 4];
        let fast = QuantizedBatch::gather_rows(&w, &ids);
        let slow = QuantizedBatch::from_rows(&ids.iter().map(|&id| w.row(id)).collect::<Vec<_>>());
        assert_eq!(fast.batch, slow.batch);
        assert_eq!(fast.alphas, slow.alphas);
        assert_eq!(fast.data, slow.data);
    }

    #[test]
    fn dequantize_is_columnwise() {
        let mut rng = Rng::new(57);
        let (batch, n) = (3, 40);
        let x = rng.normal_vec(batch * n, 0.7);
        let qb = QuantizedBatch::quantize(&x, batch, n, 2);
        let d = qb.dequantize();
        for b in 0..batch {
            assert_eq!(&d[b * n..(b + 1) * n], &qb.column(b).dequantize()[..]);
        }
    }

    #[test]
    #[should_panic(expected = "batch shape mismatch")]
    fn shape_mismatch_panics() {
        QuantizedBatch::quantize(&[0.0; 10], 3, 4, 2);
    }

    #[test]
    fn quantize_into_reuse_matches_fresh_across_shapes() {
        // One reused batch + scratch quantizes shrinking/growing shapes and
        // must match a fresh quantization every time (no stale state).
        let mut rng = Rng::new(59);
        let mut reused = QuantizedBatch::empty();
        let mut scratches = vec![QuantScratch::default()];
        let exec = Exec::serial();
        for &(batch, n, k) in &[(5usize, 70usize, 2usize), (1, 40, 3), (8, 70, 1), (3, 129, 4)] {
            let x = rng.normal_vec(batch * n, 0.6);
            let method = Method::Alternating { t: 2 };
            reused.quantize_into_exec(&x, batch, n, k, method, &exec, &mut scratches);
            let fresh = QuantizedBatch::quantize_with(&x, batch, n, k, method);
            assert_eq!(reused.batch, fresh.batch, "B={batch} n={n} k={k}");
            assert_eq!(reused.k, fresh.k, "B={batch} n={n} k={k}");
            assert_eq!(reused.alphas, fresh.alphas, "B={batch} n={n} k={k}");
            assert_eq!(reused.data, fresh.data, "B={batch} n={n} k={k}");
        }
    }

    #[test]
    fn gather_rows_into_reuse_matches_gather_rows() {
        let mut rng = Rng::new(60);
        let w = crate::quant::RowQuantized::quantize(
            &rng.normal_vec(6 * 70, 0.4),
            6,
            70,
            2,
            Method::Alternating { t: 2 },
        );
        let mut reused = QuantizedBatch::empty();
        for ids in [&[0usize, 5, 2][..], &[1usize][..], &[3usize, 3, 3, 0][..]] {
            reused.gather_rows_into(&w, ids);
            let fresh = QuantizedBatch::gather_rows(&w, ids);
            assert_eq!(reused.alphas, fresh.alphas, "{ids:?}");
            assert_eq!(reused.data, fresh.data, "{ids:?}");
            assert_eq!(reused.batch, fresh.batch, "{ids:?}");
        }
    }
}
