//! Refined greedy approximation (Guo et al. 2017; Eq. 5 of the paper):
//! greedy bit selection, but after adding bit `j` all coefficients
//! `{αᵢ}_{i≤j}` are refit by least squares. Binary codes stay fixed — the
//! limitation the paper's alternating method removes.

use super::{greedy, lsq, packed::PackedBits, Quantized};

/// k-bit refined greedy quantization.
pub fn quantize(w: &[f32], k: usize) -> Quantized {
    let n = w.len();
    let mut planes: Vec<PackedBits> = Vec::with_capacity(k);
    let mut alphas: Vec<f32> = Vec::with_capacity(k);
    for _ in 0..k {
        // Residue under the current (refit) coefficients.
        let mut residue = w.to_vec();
        for (plane, &a) in planes.iter().zip(&alphas) {
            for (j, r) in residue.iter_mut().enumerate() {
                *r -= a * plane.sign(j);
            }
        }
        let (_, plane) = greedy::step(&residue);
        planes.push(plane);
        // Refit ALL coefficients with the enlarged basis (Eq. 5).
        alphas = lsq::refit(w, &planes);
    }
    Quantized { n, alphas, planes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{greedy as g, relative_mse};
    use crate::util::prop::check_f32_vec;

    #[test]
    fn refined_never_worse_than_greedy_k2_property() {
        // For k ≤ 2 refined's planes coincide with greedy's (the k=1 refit
        // equals the greedy coefficient), so refined ≤ greedy is a theorem.
        // For k ≥ 3 the paths diverge and only holds statistically — see
        // `refined_beats_greedy_statistically`.
        check_f32_vec("refined<=greedy@k2", 300, 1.5, |w| {
            (1..=2).all(|k| {
                let eg = relative_mse(w, &g::quantize(w, k).dequantize());
                let er = relative_mse(w, &quantize(w, k).dequantize());
                er <= eg + 1e-5
            })
        });
    }

    #[test]
    fn refined_beats_greedy_statistically() {
        // Table 1: Refined < Greedy on trained (heavy-tailed) weights.
        let w = crate::util::Rng::new(35).laplace_vec(8192, 0.1);
        for k in 3..=4 {
            let eg = relative_mse(&w, &g::quantize(&w, k).dequantize());
            let er = relative_mse(&w, &quantize(&w, k).dequantize());
            assert!(er < eg, "k={k} refined={er} greedy={eg}");
        }
    }

    #[test]
    fn k1_equals_greedy() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 * 0.7).sin()).collect();
        let a = quantize(&w, 1);
        let b = g::quantize(&w, 1);
        assert!((a.alphas[0] - b.alphas[0]).abs() < 1e-5);
    }

    #[test]
    fn coefficients_are_least_squares_optimal() {
        let w: Vec<f32> = (0..200).map(|i| ((i * 31 % 97) as f32 - 48.0) / 30.0).collect();
        let q = quantize(&w, 3);
        let refit = super::lsq::refit(&w, &q.planes);
        for (a, b) in q.alphas.iter().zip(&refit) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
