//! Greedy approximation (Guo et al. 2017; Eq. 3–4 of the paper):
//! sequentially minimize the residue, one bit at a time:
//! `αᵢ = ‖rᵢ₋₁‖₁ / n`, `bᵢ = sign(rᵢ₋₁)`.

use super::packed::PackedBits;
use super::scratch::QuantScratch;
use super::Quantized;

/// One greedy step on a residue: the closed-form k=1 optimum
/// (Rastegari et al. 2016).
pub(crate) fn step(residue: &[f32]) -> (f32, PackedBits) {
    let n = residue.len();
    let alpha = if n == 0 {
        0.0
    } else {
        residue.iter().map(|x| x.abs()).sum::<f32>() / n as f32
    };
    (alpha, PackedBits::from_signs(residue))
}

/// One greedy step packed directly into a caller-provided plane word slice:
/// the same coefficient and the same sign packing as [`step`] (bit set ⇔
/// residue ≥ 0, matching `PackedBits::from_signs`), no `PackedBits`
/// allocation.
fn step_into(residue: &[f32], plane: &mut [u64]) -> f32 {
    let n = residue.len();
    let alpha = if n == 0 {
        0.0
    } else {
        residue.iter().map(|x| x.abs()).sum::<f32>() / n as f32
    };
    plane.fill(0);
    for (j, &x) in residue.iter().enumerate() {
        if x >= 0.0 {
            plane[j / 64] |= 1u64 << (j % 64);
        }
    }
    alpha
}

/// k-bit greedy quantization written directly into caller-provided buffers:
/// `alphas` (length `k`) and `planes` (`k · ⌈n/64⌉` words, layout
/// `[plane][word]`, tail bits kept zero). Bit-identical to [`quantize`] —
/// the allocating API is a thin wrapper over this core — and allocation-free
/// once `scratch` is warm.
pub fn quantize_into(
    w: &[f32],
    k: usize,
    alphas: &mut [f32],
    planes: &mut [u64],
    scratch: &mut QuantScratch,
) {
    let n = w.len();
    let wpp = n.div_ceil(64);
    assert_eq!(alphas.len(), k, "alpha buffer size mismatch");
    assert_eq!(planes.len(), k * wpp, "plane buffer size mismatch");
    scratch.residue.clear();
    scratch.residue.extend_from_slice(w);
    for (t, alpha_out) in alphas.iter_mut().enumerate() {
        let plane = &mut planes[t * wpp..(t + 1) * wpp];
        let alpha = step_into(&scratch.residue, plane);
        for (j, r) in scratch.residue.iter_mut().enumerate() {
            let sign = if (plane[j / 64] >> (j % 64)) & 1 == 1 { 1.0 } else { -1.0 };
            *r -= alpha * sign;
        }
        *alpha_out = alpha;
    }
}

/// k-bit greedy quantization.
pub fn quantize(w: &[f32], k: usize) -> Quantized {
    let n = w.len();
    let wpp = n.div_ceil(64);
    let mut alphas = vec![0.0f32; k];
    let mut words = vec![0u64; k * wpp];
    quantize_into(w, k, &mut alphas, &mut words, &mut QuantScratch::default());
    Quantized { n, alphas, planes: super::planes_from_words(n, k, &words) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::relative_mse;
    use crate::util::prop::check_f32_vec;
    use crate::util::Rng;

    #[test]
    fn k1_closed_form() {
        let w = [0.5f32, -1.5, 2.0, -0.25];
        let q = quantize(&w, 1);
        let expect = w.iter().map(|x| x.abs()).sum::<f32>() / 4.0;
        assert!((q.alphas[0] - expect).abs() < 1e-6);
        let deq = q.dequantize();
        for (x, d) in w.iter().zip(&deq) {
            assert_eq!(d.signum(), x.signum());
        }
    }

    #[test]
    fn alphas_nonincreasing_on_symmetric_data() {
        // Greedy residues shrink, so coefficients decrease for well-spread data.
        let mut rng = Rng::new(31);
        let w: Vec<f32> = (0..2048).map(|_| rng.normal()).collect();
        let q = quantize(&w, 4);
        for pair in q.alphas.windows(2) {
            assert!(pair[0] >= pair[1] - 1e-6, "{:?}", q.alphas);
        }
    }

    #[test]
    fn error_decreases_with_k_property() {
        check_f32_vec("greedy-monotone-k", 200, 2.0, |w| {
            let e2 = relative_mse(w, &quantize(w, 2).dequantize());
            let e3 = relative_mse(w, &quantize(w, 3).dequantize());
            e3 <= e2 + 1e-6
        });
    }

    #[test]
    fn constant_vector_is_exact_at_k1() {
        let w = vec![0.37f32; 129];
        let q = quantize(&w, 1);
        assert!(q.sq_error(&w) < 1e-10);
    }

    #[test]
    fn quantize_into_matches_quantize_with_dirty_buffers() {
        let mut rng = Rng::new(32);
        let mut scratch = QuantScratch::default();
        for n in [1usize, 64, 70, 130] {
            for k in 1..=4 {
                let w = rng.normal_vec(n, 0.7);
                let wpp = n.div_ceil(64);
                // Dirty buffers: stale garbage must be fully overwritten.
                let mut alphas = vec![9.9f32; k];
                let mut words = vec![u64::MAX; k * wpp];
                quantize_into(&w, k, &mut alphas, &mut words, &mut scratch);
                let q = quantize(&w, k);
                assert_eq!(alphas, q.alphas, "n={n} k={k}");
                for (t, p) in q.planes.iter().enumerate() {
                    assert_eq!(&words[t * wpp..(t + 1) * wpp], p.words(), "n={n} k={k} t={t}");
                }
            }
        }
    }
}
