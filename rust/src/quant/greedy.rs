//! Greedy approximation (Guo et al. 2017; Eq. 3–4 of the paper):
//! sequentially minimize the residue, one bit at a time:
//! `αᵢ = ‖rᵢ₋₁‖₁ / n`, `bᵢ = sign(rᵢ₋₁)`.

use super::packed::PackedBits;
use super::Quantized;

/// One greedy step on a residue: the closed-form k=1 optimum
/// (Rastegari et al. 2016).
pub(crate) fn step(residue: &[f32]) -> (f32, PackedBits) {
    let n = residue.len();
    let alpha = if n == 0 {
        0.0
    } else {
        residue.iter().map(|x| x.abs()).sum::<f32>() / n as f32
    };
    (alpha, PackedBits::from_signs(residue))
}

/// k-bit greedy quantization.
pub fn quantize(w: &[f32], k: usize) -> Quantized {
    let mut residue = w.to_vec();
    let mut alphas = Vec::with_capacity(k);
    let mut planes = Vec::with_capacity(k);
    for _ in 0..k {
        let (alpha, plane) = step(&residue);
        for (j, r) in residue.iter_mut().enumerate() {
            *r -= alpha * plane.sign(j);
        }
        alphas.push(alpha);
        planes.push(plane);
    }
    Quantized { n: w.len(), alphas, planes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::relative_mse;
    use crate::util::prop::check_f32_vec;
    use crate::util::Rng;

    #[test]
    fn k1_closed_form() {
        let w = [0.5f32, -1.5, 2.0, -0.25];
        let q = quantize(&w, 1);
        let expect = w.iter().map(|x| x.abs()).sum::<f32>() / 4.0;
        assert!((q.alphas[0] - expect).abs() < 1e-6);
        let deq = q.dequantize();
        for (x, d) in w.iter().zip(&deq) {
            assert_eq!(d.signum(), x.signum());
        }
    }

    #[test]
    fn alphas_nonincreasing_on_symmetric_data() {
        // Greedy residues shrink, so coefficients decrease for well-spread data.
        let mut rng = Rng::new(31);
        let w: Vec<f32> = (0..2048).map(|_| rng.normal()).collect();
        let q = quantize(&w, 4);
        for pair in q.alphas.windows(2) {
            assert!(pair[0] >= pair[1] - 1e-6, "{:?}", q.alphas);
        }
    }

    #[test]
    fn error_decreases_with_k_property() {
        check_f32_vec("greedy-monotone-k", 200, 2.0, |w| {
            let e2 = relative_mse(w, &quantize(w, 2).dequantize());
            let e3 = relative_mse(w, &quantize(w, 3).dequantize());
            e3 <= e2 + 1e-6
        });
    }

    #[test]
    fn constant_vector_is_exact_at_k1() {
        let w = vec![0.37f32; 129];
        let q = quantize(&w, 1);
        assert!(q.sq_error(&w) < 1e-10);
    }
}
