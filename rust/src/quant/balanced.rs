//! Balanced quantization (Zhou et al. 2017), as described in §2(b) of the
//! paper: equalize the data into `2^k` intervals containing roughly the same
//! percentage of entries, then linearly map each interval's center onto the
//! corresponding evenly spaced code of Eq. 1.
//!
//! The paper's critique — which Tables 1–2 demonstrate with very large
//! relative MSE — is that the affine mapping of *ranks* to codes ignores the
//! actual magnitudes, so the reconstruction can be arbitrarily poor on
//! heavy-tailed weights. We reproduce the method faithfully to reproduce
//! that observation.

use super::{packed::PackedBits, Quantized};

/// k-bit balanced quantization.
pub fn quantize(w: &[f32], k: usize) -> Quantized {
    assert!(k >= 1 && k <= 16);
    let n = w.len();
    let m = 1usize << k;
    let s = w.iter().fold(0.0f32, |mx, &x| mx.max(x.abs()));
    let mut planes = vec![PackedBits::zeros(n); k];
    if n > 0 && s > 0.0 {
        // Rank-equalize: sort indices by value, split into 2^k equal-count
        // buckets; bucket j maps to uniform level j.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| w[a].total_cmp(&w[b]));
        for (rank, &j) in order.iter().enumerate() {
            // Evenly spread ranks over buckets (first buckets get the
            // remainder, matching "roughly the same percentage").
            let bucket = (rank * m / n).min(m - 1) as u32;
            for (i, plane) in planes.iter_mut().enumerate() {
                if (bucket >> i) & 1 == 1 {
                    plane.set(j, true);
                }
            }
        }
    }
    let denom = ((1u32 << k) - 1) as f32;
    let alphas = (0..k).map(|i| s * (1u32 << i) as f32 / denom).collect();
    Quantized { n, alphas, planes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::relative_mse;
    use crate::util::Rng;

    #[test]
    fn buckets_are_balanced() {
        let mut rng = Rng::new(61);
        let w: Vec<f32> = (0..1024).map(|_| rng.normal()).collect();
        let q = quantize(&w, 2);
        // Count entries per composite level.
        let mut counts = [0usize; 4];
        for j in 0..w.len() {
            let idx = (q.planes[0].get(j) as usize) | ((q.planes[1].get(j) as usize) << 1);
            counts[idx] += 1;
        }
        for c in counts {
            assert_eq!(c, 256, "balanced buckets must be equal-count: {counts:?}");
        }
    }

    #[test]
    fn order_preserving() {
        // Larger weight never maps to a smaller level.
        let mut rng = Rng::new(62);
        let w: Vec<f32> = (0..257).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let q = quantize(&w, 3);
        let d = q.dequantize();
        let mut idx: Vec<usize> = (0..w.len()).collect();
        idx.sort_by(|&a, &b| w[a].total_cmp(&w[b]));
        for pair in idx.windows(2) {
            assert!(d[pair[0]] <= d[pair[1]] + 1e-6);
        }
    }

    #[test]
    fn poor_on_heavy_tails_as_paper_observes() {
        // Gaussian weights: balanced should be much worse than greedy
        // (Table 1: 0.891 vs 0.146 at 2 bits).
        let w = Rng::new(63).normal_vec(8192, 1.0);
        let eb = relative_mse(&w, &quantize(&w, 2).dequantize());
        let eg = relative_mse(&w, &crate::quant::greedy::quantize(&w, 2).dequantize());
        assert!(eb > 2.0 * eg, "balanced {eb} vs greedy {eg}");
    }

    #[test]
    fn zero_and_empty() {
        assert!(quantize(&[0.0; 8], 2).dequantize().iter().all(|&x| x == 0.0));
        let q = quantize(&[], 2);
        assert_eq!(q.n, 0);
    }
}
