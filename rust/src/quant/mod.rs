//! Multi-bit quantization: `w ≈ Σᵢ αᵢ bᵢ`, `bᵢ ∈ {−1,+1}ⁿ`.
//!
//! This module implements the paper's core contribution — **alternating
//! minimization** (Algorithm 2) with optimal binary-code assignment by
//! **binary search tree** (Algorithm 1) — together with every baseline the
//! paper compares against in Section 2:
//!
//! | method        | module          | paper reference            |
//! |---------------|-----------------|----------------------------|
//! | Uniform       | [`uniform`]     | Eq. 1 (Hubara et al.)      |
//! | Balanced      | [`balanced`]    | Zhou et al. 2017           |
//! | Greedy        | [`greedy`]      | Eq. 3–4 (Guo et al.)       |
//! | Refined       | [`refined`]     | Eq. 5 (Guo et al.)         |
//! | Ternary       | [`ternary`]     | Li et al. 2016             |
//! | Alternating   | [`alternating`] | Algorithms 1 + 2 (ours)    |
//!
//! All methods produce the same representation, [`Quantized`]: `k` real
//! coefficients plus `k` bit-packed sign planes, which feeds directly into
//! the XNOR/popcount kernels in [`crate::kernels::binary`].

pub mod alternating;
pub mod balanced;
pub mod batch;
pub mod bst;
pub mod greedy;
pub mod lsq;
pub mod matrix;
pub mod packed;
pub mod refined;
pub mod scratch;
pub mod ternary;
pub mod uniform;

pub use batch::QuantizedBatch;
pub use matrix::RowQuantized;
pub use packed::PackedBits;
pub use scratch::QuantScratch;

/// Split a contiguous `[plane][word]` buffer into per-plane [`PackedBits`]
/// (the cold-path adapter behind the allocating quantizer wrappers).
pub(crate) fn planes_from_words(n: usize, k: usize, words: &[u64]) -> Vec<PackedBits> {
    let wpp = n.div_ceil(64);
    (0..k)
        .map(|t| PackedBits::from_words(n, words[t * wpp..(t + 1) * wpp].to_vec()))
        .collect()
}

/// A k-bit quantized vector: `ŵ = Σᵢ alphas[i] · planes[i]` where plane bits
/// map `1 → +1`, `0 → −1`.
#[derive(Clone, Debug)]
pub struct Quantized {
    /// Logical length `n` of the vector.
    pub n: usize,
    /// The real coefficients `αᵢ`, one per bit.
    pub alphas: Vec<f32>,
    /// The binary codes `bᵢ`, bit-packed, one plane per bit.
    pub planes: Vec<PackedBits>,
}

impl Quantized {
    /// Number of bits `k`.
    pub fn k(&self) -> usize {
        self.alphas.len()
    }

    /// Reconstruct the dense approximation `ŵ`.
    ///
    /// Accumulates plane by plane directly over the packed words (one shift
    /// per element) instead of re-extracting each bit with `sign(i)` — this
    /// path backs the dense fallbacks and most tests, so the O(n·k)
    /// bit-indexing cost matters. The per-element additions happen in the
    /// same (plane-major) order as before, so results are bit-identical.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        for (&alpha, plane) in self.alphas.iter().zip(&self.planes) {
            for (wi, &word) in plane.words().iter().enumerate() {
                let base = wi * 64;
                let live = 64.min(self.n - base);
                let chunk = &mut out[base..base + live];
                let mut bits = word;
                for o in chunk.iter_mut() {
                    *o += if bits & 1 == 1 { alpha } else { -alpha };
                    bits >>= 1;
                }
            }
        }
        out
    }

    /// Squared reconstruction error `‖w − ŵ‖²` against the original vector.
    pub fn sq_error(&self, w: &[f32]) -> f64 {
        assert_eq!(w.len(), self.n);
        let hat = self.dequantize();
        w.iter()
            .zip(&hat)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum()
    }
}

/// Which quantization algorithm to run (see module table above).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Uniform,
    Balanced,
    Greedy,
    Refined,
    /// The paper's method with `t` alternating cycles (paper uses `t = 2`).
    Alternating {
        t: usize,
    },
    /// 2-bit only; `k` argument is ignored (forced to 2).
    Ternary,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Uniform => "Uniform",
            Method::Balanced => "Balanced",
            Method::Greedy => "Greedy",
            Method::Refined => "Refined",
            Method::Alternating { .. } => "Alternating",
            Method::Ternary => "Ternary",
        }
    }

    /// All methods compared in Tables 1–2, in the paper's row order.
    pub fn table_order() -> [Method; 5] {
        [
            Method::Uniform,
            Method::Balanced,
            Method::Greedy,
            Method::Refined,
            Method::Alternating { t: 2 },
        ]
    }
}

/// Canonical flag spelling: lowercase name, with the cycle count appended
/// for non-default alternating (`alternating:3`). Round-trips with the
/// `FromStr` impl below, so `--method` output can be pasted back verbatim.
impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Method::Uniform => write!(f, "uniform"),
            Method::Balanced => write!(f, "balanced"),
            Method::Greedy => write!(f, "greedy"),
            Method::Refined => write!(f, "refined"),
            Method::Alternating { t: 2 } => write!(f, "alternating"),
            Method::Alternating { t } => write!(f, "alternating:{t}"),
            Method::Ternary => write!(f, "ternary"),
        }
    }
}

/// Parse a method flag: `uniform | balanced | greedy | refined |
/// alternating[:cycles] | ternary` (case-insensitive; `alternating`
/// defaults to the paper's `T = 2`).
impl std::str::FromStr for Method {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let lower = s.trim().to_ascii_lowercase();
        let (name, arg) = match lower.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (lower.as_str(), None),
        };
        let method = match name {
            "uniform" => Method::Uniform,
            "balanced" => Method::Balanced,
            "greedy" => Method::Greedy,
            "refined" => Method::Refined,
            "alternating" | "alt" => {
                let t = match arg {
                    None => 2,
                    Some(a) => a
                        .parse::<usize>()
                        .ok()
                        .filter(|&t| t >= 1)
                        .ok_or_else(|| format!("bad cycle count '{a}' in method '{s}'"))?,
                };
                return Ok(Method::Alternating { t });
            }
            "ternary" => Method::Ternary,
            _ => {
                return Err(format!(
                    "unknown method '{s}' (uniform|balanced|greedy|refined|alternating[:cycles]|ternary)"
                ))
            }
        };
        if arg.is_some() {
            return Err(format!("method '{name}' takes no ':' argument (got '{s}')"));
        }
        Ok(method)
    }
}

/// Quantize a vector with the chosen method.
pub fn quantize(w: &[f32], k: usize, method: Method) -> Quantized {
    match method {
        Method::Uniform => uniform::quantize(w, k),
        Method::Balanced => balanced::quantize(w, k),
        Method::Greedy => greedy::quantize(w, k),
        Method::Refined => refined::quantize(w, k),
        Method::Alternating { t } => alternating::quantize(w, k, t),
        Method::Ternary => ternary::quantize(w),
    }
}

/// Quantize one vector directly into caller-provided coefficient and packed
/// plane buffers. Greedy and Alternating (the serving methods) run the
/// fused zero-allocation `_into` core; the remaining baselines fall back to
/// the allocating quantizer and copy — their codes are not residue-local,
/// so fusing them buys nothing, and the caller's buffers are still reused.
/// Buffer sizes follow the *emitted* width (`k`, except Ternary's fixed 2).
/// Bit-identical to [`quantize`] for every method.
pub fn quantize_row_into(
    w: &[f32],
    k: usize,
    method: Method,
    alphas: &mut [f32],
    planes: &mut [u64],
    scratch: &mut QuantScratch,
) {
    match method {
        Method::Greedy => greedy::quantize_into(w, k, alphas, planes, scratch),
        Method::Alternating { t } => alternating::quantize_into(w, k, t, alphas, planes, scratch),
        _ => {
            let q = quantize(w, k, method);
            let wpp = w.len().div_ceil(64);
            assert_eq!(alphas.len(), q.k(), "alpha buffer size mismatch");
            assert_eq!(planes.len(), q.k() * wpp, "plane buffer size mismatch");
            alphas.copy_from_slice(&q.alphas);
            for (t, p) in q.planes.iter().enumerate() {
                planes[t * wpp..(t + 1) * wpp].copy_from_slice(p.words());
            }
        }
    }
}

/// Relative mean squared error `‖w − ŵ‖² / ‖w‖²` — the measure reported in
/// Tables 1–2 of the paper.
pub fn relative_mse(w: &[f32], w_hat: &[f32]) -> f64 {
    assert_eq!(w.len(), w_hat.len());
    let num: f64 = w
        .iter()
        .zip(w_hat)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    let den: f64 = w.iter().map(|&a| (a as f64).powi(2)).sum();
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn wvec(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(n, 0.3)
    }

    #[test]
    fn all_methods_produce_valid_output() {
        let w = wvec(257, 1);
        for m in Method::table_order() {
            for k in 2..=4 {
                let q = quantize(&w, k, m);
                assert_eq!(q.n, w.len());
                assert_eq!(q.k(), k, "{m:?}");
                let err = relative_mse(&w, &q.dequantize());
                assert!(err.is_finite(), "{m:?} k={k} err={err}");
            }
        }
    }

    #[test]
    fn method_quality_ordering_matches_paper() {
        // Table 1 ordering: Alternating <= Refined, and both far below the
        // rule-based methods. Trained weights are heavy-tailed, which is
        // exactly why max-scaled Uniform degrades — model them as Laplace.
        let w = Rng::new(2).laplace_vec(8192, 0.1);
        for k in 2..=4 {
            let err = |m| {
                let q = quantize(&w, k, m);
                relative_mse(&w, &q.dequantize())
            };
            let alt = err(Method::Alternating { t: 2 });
            let refined = err(Method::Refined);
            let greedy = err(Method::Greedy);
            let uniform = err(Method::Uniform);
            let balanced = err(Method::Balanced);
            assert!(alt <= refined + 1e-6, "k={k} alt={alt} refined={refined}");
            assert!(alt < uniform, "k={k} alt={alt} uniform={uniform}");
            assert!(alt < balanced, "k={k} alt={alt} balanced={balanced}");
            if k == 2 {
                // Greedy's sequential residue fitting loses steam at high k
                // (paper: 0.146→0.042 vs alternating 0.125→0.019); the clear
                // win over rule-based uniform is at low bit width.
                assert!(greedy < uniform, "k={k} greedy={greedy} uniform={uniform}");
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let w = wvec(1024, 3);
        let mut prev = f64::INFINITY;
        for k in 1..=6 {
            let q = quantize(&w, k, Method::Alternating { t: 2 });
            let e = relative_mse(&w, &q.dequantize());
            assert!(e <= prev + 1e-6, "k={k}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn relative_mse_basics() {
        assert_eq!(relative_mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!(relative_mse(&[0.0], &[1.0]).is_infinite());
        assert_eq!(relative_mse(&[0.0], &[0.0]), 0.0);
        let e = relative_mse(&[1.0, 0.0], &[0.0, 0.0]);
        assert!((e - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dequantize_matches_per_bit_reference() {
        // The word-wise fast path must equal the obvious per-bit sum.
        let w = wvec(131, 9); // odd length exercises the tail word
        for k in 1..=4 {
            let q = quantize(&w, k, Method::Alternating { t: 2 });
            let fast = q.dequantize();
            let mut slow = vec![0.0f32; q.n];
            for (alpha, plane) in q.alphas.iter().zip(&q.planes) {
                for (i, o) in slow.iter_mut().enumerate() {
                    *o += alpha * plane.sign(i);
                }
            }
            assert_eq!(fast, slow, "k={k}");
        }
    }

    #[test]
    fn method_display_fromstr_roundtrip() {
        let all = [
            Method::Uniform,
            Method::Balanced,
            Method::Greedy,
            Method::Refined,
            Method::Alternating { t: 2 },
            Method::Alternating { t: 5 },
            Method::Ternary,
        ];
        for m in all {
            let parsed: Method = m.to_string().parse().unwrap();
            assert_eq!(parsed, m, "{m}");
        }
        assert_eq!("ALTERNATING:3".parse::<Method>().unwrap(), Method::Alternating { t: 3 });
        assert_eq!("alt".parse::<Method>().unwrap(), Method::Alternating { t: 2 });
        assert!("nope".parse::<Method>().is_err());
        assert!("alternating:0".parse::<Method>().is_err());
        assert!("greedy:2".parse::<Method>().is_err());
    }

    #[test]
    fn zero_vector_quantizes_to_zero_error_alternating() {
        let w = vec![0.0f32; 64];
        let q = quantize(&w, 2, Method::Alternating { t: 2 });
        assert!(q.sq_error(&w) < 1e-12);
    }
}
