//! Algorithm 1 — optimal binary-code assignment by binary search tree.
//!
//! Key observation of the paper: with coefficients `{αᵢ}` fixed, the `2^k`
//! composite codes `v = {Σᵢ ±αᵢ}` are known, and the optimal code for each
//! weight entry is simply the nearest `v` — found in `k` comparisons by
//! descending the balanced BST over the sorted code vector (equivalently, a
//! binary search against the midpoints of adjacent codes).

use super::packed::PackedBits;
use super::scratch::QuantScratch;

/// A composite code: its real value and the sign pattern that produced it
/// (`pattern` bit `i` set ⇔ `bᵢ = +1`).
#[derive(Clone, Copy, Debug)]
pub struct Code {
    pub value: f32,
    pub pattern: u32,
}

/// [`enumerate_codes`] into a reused buffer (cleared first). The sort is
/// the same stable total-order sort as before, so tie patterns land in
/// enumeration order; for the paper's `k ≤ 4` the `2^k ≤ 16` slice sorts by
/// insertion with **no allocation**.
pub fn enumerate_codes_into(alphas: &[f32], codes: &mut Vec<Code>) {
    let k = alphas.len();
    assert!(k >= 1 && k <= 16, "k = {k} out of range");
    let m = 1usize << k;
    codes.clear();
    codes.reserve(m);
    for pattern in 0..m as u32 {
        let mut v = 0.0f32;
        for (i, &a) in alphas.iter().enumerate() {
            if (pattern >> i) & 1 == 1 {
                v += a;
            } else {
                v -= a;
            }
        }
        codes.push(Code { value: v, pattern });
    }
    codes.sort_by(|a, b| a.value.total_cmp(&b.value));
}

/// Enumerate all `2^k` composite codes `Σᵢ ±αᵢ` in ascending order.
///
/// Coefficients may be negative or unordered (they come out of an
/// unconstrained least-squares refit); enumeration + sort handles any sign.
/// Panics if `k > 16` (the representation is pointless beyond a few bits).
pub fn enumerate_codes(alphas: &[f32]) -> Vec<Code> {
    let mut codes = Vec::new();
    enumerate_codes_into(alphas, &mut codes);
    codes
}

/// [`midpoints`] into a reused buffer (cleared first).
pub fn midpoints_into(codes: &[Code], mids: &mut Vec<f32>) {
    mids.clear();
    mids.reserve(codes.len().saturating_sub(1));
    for w in codes.windows(2) {
        mids.push(0.5 * (w[0].value + w[1].value));
    }
}

/// The decision boundaries: midpoints of adjacent sorted codes
/// (`(vᵢ + vᵢ₊₁)/2`, Fig. 1 of the paper).
pub fn midpoints(codes: &[Code]) -> Vec<f32> {
    let mut mids = Vec::new();
    midpoints_into(codes, &mut mids);
    mids
}

/// Assign one entry: index into `codes` of the nearest composite code.
///
/// `mids` must be `midpoints(codes)`. This is the BST descent of
/// Algorithm 1: `partition_point` performs exactly the `k` comparisons of a
/// balanced binary search (`w ≥ midpoint → right subtree`).
#[inline]
pub fn assign_one(w: f32, mids: &[f32]) -> usize {
    mids.partition_point(|&mp| w >= mp)
}

/// [`assign`] written directly into caller-provided packed plane words
/// (`k · ⌈n/64⌉` words, layout `[plane][word]`, cleared first so tail bits
/// stay zero). Bit-identical to [`assign`] — the allocating API is a thin
/// wrapper over this core — and allocation-free once `scratch` is warm
/// (for `k ≤ 4`; see [`enumerate_codes_into`]).
pub fn assign_into(w: &[f32], alphas: &[f32], planes: &mut [u64], scratch: &mut QuantScratch) {
    let k = alphas.len();
    let wpp = w.len().div_ceil(64);
    assert_eq!(planes.len(), k * wpp, "plane buffer size mismatch");
    enumerate_codes_into(alphas, &mut scratch.codes);
    midpoints_into(&scratch.codes, &mut scratch.mids);
    planes.fill(0);
    for (j, &x) in w.iter().enumerate() {
        let idx = assign_one(x, &scratch.mids);
        let pattern = scratch.codes[idx].pattern;
        let (wi, bit) = (j / 64, 1u64 << (j % 64));
        for i in 0..k {
            if (pattern >> i) & 1 == 1 {
                planes[i * wpp + wi] |= bit;
            }
        }
    }
}

/// Assign every entry of `w` to its optimal code and return the `k` binary
/// planes (bit `1 → +1`), given fixed coefficients `alphas`.
pub fn assign(w: &[f32], alphas: &[f32]) -> Vec<PackedBits> {
    let k = alphas.len();
    let wpp = w.len().div_ceil(64);
    let mut words = vec![0u64; k * wpp];
    assign_into(w, alphas, &mut words, &mut QuantScratch::default());
    super::planes_from_words(w.len(), k, &words)
}

/// Reconstruction from planes + alphas at a single index (test helper).
pub fn reconstruct_at(planes: &[PackedBits], alphas: &[f32], j: usize) -> f32 {
    planes
        .iter()
        .zip(alphas)
        .map(|(p, &a)| a * p.sign(j))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    #[test]
    fn enumerate_is_sorted_and_complete() {
        let codes = enumerate_codes(&[0.7, 0.3, 0.1]);
        assert_eq!(codes.len(), 8);
        for w in codes.windows(2) {
            assert!(w[0].value <= w[1].value);
        }
        // Patterns are a permutation of 0..8.
        let mut pats: Vec<u32> = codes.iter().map(|c| c.pattern).collect();
        pats.sort_unstable();
        assert_eq!(pats, (0..8).collect::<Vec<u32>>());
    }

    #[test]
    fn fig1_example_2bit() {
        // Fig. 1: with α1 ≥ α2 the codes are {−α1−α2, −α1+α2, α1−α2, α1+α2}
        // and the boundaries are −α1, 0, α1.
        let codes = enumerate_codes(&[0.8, 0.3]);
        let vals: Vec<f32> = codes.iter().map(|c| c.value).collect();
        assert_eq!(vals, vec![-1.1, -0.5, 0.5, 1.1]);
        let mids = midpoints(&codes);
        assert_eq!(mids, vec![-0.8, 0.0, 0.8]);
        // Entries quantize to the nearest code.
        assert_eq!(assign_one(-0.9, &mids), 0);
        assert_eq!(assign_one(-0.6, &mids), 1);
        assert_eq!(assign_one(0.1, &mids), 2);
        assert_eq!(assign_one(2.0, &mids), 3);
    }

    #[test]
    fn closed_form_2bit_matches_bst() {
        // Paper §3: for k=2 with α1 ≥ α2 ≥ 0 the optimum is
        // b1 = sign(w), b2 = sign(w − α1·b1).
        let alphas = [0.9f32, 0.4];
        let mut rng = Rng::new(11);
        let w: Vec<f32> = (0..500).map(|_| rng.range_f32(-2.0, 2.0)).collect();
        let planes = assign(&w, &alphas);
        for (j, &x) in w.iter().enumerate() {
            let b1 = if x >= 0.0 { 1.0 } else { -1.0 };
            let b2 = if x - alphas[0] * b1 >= 0.0 { 1.0 } else { -1.0 };
            let expect = alphas[0] * b1 + alphas[1] * b2;
            let got = reconstruct_at(&planes, &alphas, j);
            // Both must achieve the same distance (tie patterns may differ).
            assert!(
                ((x - got).abs() - (x - expect).abs()).abs() < 1e-6,
                "j={j} x={x} got={got} expect={expect}"
            );
        }
    }

    #[test]
    fn bst_is_argmin_over_all_codes_property() {
        // Property: BST assignment achieves the minimal |w − v| over ALL 2^k
        // codes, for arbitrary (possibly negative/unsorted) alphas.
        prop::check(
            "bst-argmin",
            prop::Config { cases: 200, ..Default::default() },
            |rng| {
                let k = 1 + rng.below(4);
                let alphas: Vec<f32> = (0..k).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let w: Vec<f32> = (0..17).map(|_| rng.range_f32(-3.0, 3.0)).collect();
                (alphas, w)
            },
            |_| vec![],
            |(alphas, w)| {
                let codes = enumerate_codes(alphas);
                let mids = midpoints(&codes);
                w.iter().all(|&x| {
                    let idx = assign_one(x, &mids);
                    let got = (x - codes[idx].value).abs();
                    let best = codes
                        .iter()
                        .map(|c| (x - c.value).abs())
                        .fold(f32::INFINITY, f32::min);
                    (got - best).abs() <= 1e-5 * (1.0 + best)
                })
            },
        );
    }

    #[test]
    fn assign_planes_reconstruct_to_codes() {
        let alphas = [0.5f32, -0.2, 0.05];
        let mut rng = Rng::new(12);
        let w: Vec<f32> = (0..200).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let planes = assign(&w, &alphas);
        let codes = enumerate_codes(&alphas);
        let mids = midpoints(&codes);
        for (j, &x) in w.iter().enumerate() {
            let expect = codes[assign_one(x, &mids)].value;
            let got = reconstruct_at(&planes, &alphas, j);
            assert!((got - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn k1_is_sign() {
        let mids = midpoints(&enumerate_codes(&[0.5]));
        assert_eq!(mids, vec![0.0]);
        assert_eq!(assign_one(-0.1, &mids), 0);
        assert_eq!(assign_one(0.1, &mids), 1);
    }
}
