//! Ternary quantization (Li et al. 2016), per §2 of the paper: quantize onto
//! `{−α, 0, +α}` with the empirical threshold `Δ = 0.7·‖w‖₁/n`; entries with
//! `|w| ≤ Δ` become 0, the rest `±α` with `α` the least-squares optimum over
//! the non-zero support (the mean magnitude of the kept entries).
//!
//! As the paper notes, ternary is the special case of 2-bit quantization
//! with `α₁ = α₂`, so we emit it in the common 2-plane representation
//! (`t = (b₁ + b₂)/2` scaled): `α₁ = α₂ = α/2`, both planes equal to
//! `sign(w)` on the support, opposite off it.

use super::{packed::PackedBits, Quantized};

/// Ternary quantization (always 2 planes).
pub fn quantize(w: &[f32]) -> Quantized {
    let n = w.len();
    let delta = if n == 0 {
        0.0
    } else {
        0.7 * w.iter().map(|x| x.abs()).sum::<f32>() / n as f32
    };
    let mut kept_sum = 0.0f64;
    let mut kept = 0usize;
    let mut p1 = PackedBits::zeros(n);
    let mut p2 = PackedBits::zeros(n);
    for (j, &x) in w.iter().enumerate() {
        if x.abs() > delta {
            kept_sum += x.abs() as f64;
            kept += 1;
            let pos = x >= 0.0;
            p1.set(j, pos);
            p2.set(j, pos);
        } else {
            // +α/2 − α/2 = 0.
            p1.set(j, true);
            p2.set(j, false);
        }
    }
    let alpha = if kept > 0 { (kept_sum / kept as f64) as f32 } else { 0.0 };
    Quantized { n, alphas: vec![alpha / 2.0, alpha / 2.0], planes: vec![p1, p2] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_f32_vec;
    use crate::util::Rng;

    #[test]
    fn output_is_ternary_property() {
        check_f32_vec("ternary-levels", 300, 2.0, |w| {
            let q = quantize(w);
            let alpha = q.alphas[0] * 2.0;
            q.dequantize().iter().all(|&v| {
                v.abs() < 1e-6 || (v.abs() - alpha).abs() < 1e-5 * (1.0 + alpha)
            })
        });
    }

    #[test]
    fn threshold_rule() {
        let w = [1.0f32, -1.0, 0.1, -0.1]; // mean |w| = 0.55, Δ = 0.385
        let q = quantize(&w);
        let d = q.dequantize();
        assert!(d[0] > 0.0 && d[1] < 0.0);
        assert!(d[2].abs() < 1e-6 && d[3].abs() < 1e-6);
        // α = mean of kept magnitudes = 1.0.
        assert!((q.alphas[0] * 2.0 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn worse_than_free_2bit_alternating() {
        // Ternary constrains α₁ = α₂, so unconstrained 2-bit must be ≤ error.
        let w = Rng::new(71).normal_vec(4096, 1.0);
        let et = quantize(&w).sq_error(&w);
        let ea = crate::quant::alternating::quantize(&w, 2, 2).sq_error(&w);
        assert!(ea <= et + 1e-4, "alternating {ea} vs ternary {et}");
    }

    #[test]
    fn zero_vector() {
        let q = quantize(&[0.0; 16]);
        assert!(q.dequantize().iter().all(|&x| x.abs() < 1e-12));
    }
}
