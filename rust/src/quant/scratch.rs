//! Reusable quantizer scratch — the allocation-free substrate of the
//! fused `_into` quantization APIs.
//!
//! One [`QuantScratch`] holds every intermediate buffer the greedy /
//! least-squares / BST pipeline needs (the greedy residue, the `k×k` Gram
//! system, the `2^k` composite codes and their midpoints). Each buffer is
//! fully rewritten per call, so a scratch carries no state between rows —
//! any row quantized with any (warm or dirty) scratch produces bit-identical
//! output. Buffers grow to the high-water mark of the shapes they have seen
//! and are then reused: after one warm-up call at a given `(n, k)`, every
//! further call at sizes up to that mark performs **zero heap allocations**
//! (for the paper's `k ≤ 4`; at `k ≥ 5` the code sort spills to an
//! allocating merge sort, which no serving path reaches).
//!
//! Threading contract: a scratch is *not* shared between concurrent tasks —
//! callers that shard rows across workers hold one scratch per task (see
//! [`crate::quant::QuantizedBatch::quantize_into_exec`]).

use super::bst::Code;

/// Scratch buffers for one quantizer task. See the module docs for the
/// reuse and threading contract.
#[derive(Default, Debug)]
pub struct QuantScratch {
    /// Greedy residue, length `n`.
    pub(crate) residue: Vec<f32>,
    /// The `2^k` composite codes of the BST assignment.
    pub(crate) codes: Vec<Code>,
    /// The `2^k − 1` decision boundaries.
    pub(crate) mids: Vec<f32>,
    /// Exact `k×k` Gram matrix of the LSQ refit (row-major).
    pub(crate) gram: Vec<f64>,
    /// Working copy of the Gram matrix consumed by elimination.
    pub(crate) gram_w: Vec<f64>,
    /// Exact right-hand side `Bᵀw`.
    pub(crate) rhs: Vec<f64>,
    /// Working copy of the right-hand side consumed by elimination.
    pub(crate) rhs_w: Vec<f64>,
    /// Solution vector of the `k×k` solve.
    pub(crate) sol: Vec<f64>,
}

impl QuantScratch {
    pub fn new() -> Self {
        Self::default()
    }
}
