//! Uniform (rule-based) quantization, Eq. 1 of the paper
//! (Rastegari et al. 2016; Hubara et al. 2016b):
//!
//! ```text
//! q_k(x) = 2 * ( round[(2^k − 1) (x+1)/2] / (2^k − 1) − 1/2 ),  x ∈ [−1, 1]
//! ```
//!
//! scaled into `[−1, 1]` by `s = max|w|` and back. The `2^k` evenly spaced
//! levels are exactly representable in the multi-bit form with
//! `αᵢ = s·2^i / (2^k − 1)` and plane `i` = bit `i` of the level index, so
//! uniform quantization runs on the same XNOR/popcount kernels.

use super::{packed::PackedBits, Quantized};

/// Level index in `[0, 2^k)` for `x ∈ [−s, s]`.
#[inline]
fn level(x: f32, s: f32, k: usize) -> u32 {
    let m = ((1u32 << k) - 1) as f32;
    let t = ((x / s).clamp(-1.0, 1.0) + 1.0) / 2.0; // ∈ [0,1]
    (t * m).round() as u32
}

/// k-bit uniform quantization.
pub fn quantize(w: &[f32], k: usize) -> Quantized {
    assert!(k >= 1 && k <= 16);
    let n = w.len();
    let s = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let mut planes = vec![PackedBits::zeros(n); k];
    if s > 0.0 {
        for (j, &x) in w.iter().enumerate() {
            let idx = level(x, s, k);
            for (i, plane) in planes.iter_mut().enumerate() {
                if (idx >> i) & 1 == 1 {
                    plane.set(j, true);
                }
            }
        }
    }
    let denom = ((1u32 << k) - 1) as f32;
    let alphas = (0..k).map(|i| s * (1u32 << i) as f32 / denom).collect();
    Quantized { n, alphas, planes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::relative_mse;
    use crate::util::prop::check_f32_vec;

    #[test]
    fn levels_are_evenly_spaced_and_hit_extremes() {
        // k=2 on [-1,1]: levels must be {-1, -1/3, 1/3, 1}.
        let w = [-1.0f32, -0.34, 0.34, 1.0];
        let q = quantize(&w, 2);
        let d = q.dequantize();
        let expect = [-1.0, -1.0 / 3.0, 1.0 / 3.0, 1.0];
        for (a, b) in d.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6, "{d:?}");
        }
    }

    #[test]
    fn representation_matches_direct_formula_property() {
        // The multi-bit (alphas, planes) encoding must reproduce q_k exactly.
        check_f32_vec("uniform-encoding", 200, 3.0, |w| {
            let s = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            if s == 0.0 {
                return true;
            }
            for k in 1..=4 {
                let q = quantize(w, k);
                let d = q.dequantize();
                let m = ((1u32 << k) - 1) as f32;
                for (&x, &dx) in w.iter().zip(&d) {
                    let t = ((x / s) + 1.0) / 2.0;
                    let direct = s * 2.0 * ((t * m).round() / m - 0.5);
                    if (dx - direct).abs() > 1e-5 * (1.0 + s) {
                        return false;
                    }
                }
            }
            true
        });
    }

    #[test]
    fn zero_vector() {
        let q = quantize(&[0.0; 10], 3);
        assert!(q.dequantize().iter().all(|&x| x.abs() < 1e-12));
    }

    #[test]
    fn worse_than_greedy_on_gaussian() {
        // The paper's point: rule-based uniform is far from optimal on
        // non-uniform (gaussian) data.
        let w = crate::util::Rng::new(51).normal_vec(4096, 1.0);
        let eu = relative_mse(&w, &quantize(&w, 2).dequantize());
        let eg = relative_mse(&w, &crate::quant::greedy::quantize(&w, 2).dequantize());
        assert!(eu > eg, "uniform {eu} should exceed greedy {eg}");
    }
}
