//! Bit-packed binary codes.
//!
//! A [`PackedBits`] stores one binary plane `b ∈ {−1,+1}ⁿ` as `⌈n/64⌉` words
//! with the convention `bit = 1 → +1`, `bit = 0 → −1`. Tail bits beyond `n`
//! are kept **zero** in every plane so that XOR-based dot products never see
//! garbage (two equal pads XOR to zero and drop out of the popcount).

/// One bit-packed binary plane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedBits {
    n: usize,
    words: Vec<u64>,
}

impl PackedBits {
    /// All −1 (all bits clear).
    pub fn zeros(n: usize) -> Self {
        PackedBits { n, words: vec![0; n.div_ceil(64)] }
    }

    /// Pack from signs: `v[i] >= 0` maps to `+1` (matching `sign` with the
    /// paper's tie-break `sign(0) = +1`).
    pub fn from_signs(v: &[f32]) -> Self {
        let mut p = PackedBits::zeros(v.len());
        for (i, &x) in v.iter().enumerate() {
            if x >= 0.0 {
                p.set(i, true);
            }
        }
        p
    }

    /// Rebuild from raw words (e.g. a plane sliced out of a contiguous
    /// batch buffer). Tail bits beyond `n` must be zero — enforced here
    /// unconditionally, because a nonzero pad would silently corrupt every
    /// XOR/popcount dot product downstream.
    pub fn from_words(n: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), n.div_ceil(64), "word count mismatch for n={n}");
        if n % 64 != 0 {
            if let Some(&last) = words.last() {
                assert_eq!(last >> (n % 64), 0, "tail bits beyond n={n} must be zero");
            }
        }
        PackedBits { n, words }
    }

    /// Pack from booleans (`true → +1`).
    pub fn from_bools(v: &[bool]) -> Self {
        let mut p = PackedBits::zeros(v.len());
        for (i, &b) in v.iter().enumerate() {
            if b {
                p.set(i, true);
            }
        }
        p
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Raw words (tail bits are guaranteed zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.n);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.n);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// The sign value `±1.0` at position `i`.
    #[inline]
    pub fn sign(&self, i: usize) -> f32 {
        if self.get(i) {
            1.0
        } else {
            -1.0
        }
    }

    /// Unpack to a dense sign vector.
    pub fn to_signs(&self) -> Vec<f32> {
        (0..self.n).map(|i| self.sign(i)).collect()
    }

    /// Number of `+1` entries.
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Integer dot product `⟨a, b⟩ = n − 2·popcount(a ⊕ b)` over `{−1,+1}ⁿ`.
    ///
    /// This is the identity the paper's CPU kernel (Appendix A) exploits:
    /// XNOR + popcount replaces multiply–accumulate. Pads are zero in both
    /// operands so they vanish under XOR.
    #[inline]
    pub fn dot_i32(&self, other: &PackedBits) -> i32 {
        debug_assert_eq!(self.n, other.n);
        let mut mismatches = 0u32;
        for (a, b) in self.words.iter().zip(&other.words) {
            mismatches += (a ^ b).count_ones();
        }
        self.n as i32 - 2 * mismatches as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_f32_vec;
    use crate::util::Rng;

    #[test]
    fn roundtrip_signs() {
        let v = [1.0f32, -2.0, 0.0, -0.5, 3.0, -1.0, 1.0];
        let p = PackedBits::from_signs(&v);
        let s = p.to_signs();
        assert_eq!(s, vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0]);
    }

    #[test]
    fn get_set() {
        let mut p = PackedBits::zeros(130);
        p.set(0, true);
        p.set(64, true);
        p.set(129, true);
        assert!(p.get(0) && p.get(64) && p.get(129));
        assert!(!p.get(1) && !p.get(63) && !p.get(128));
        p.set(64, false);
        assert!(!p.get(64));
        assert_eq!(p.count_ones(), 2);
    }

    #[test]
    fn tail_bits_stay_zero() {
        let v: Vec<f32> = (0..70).map(|_| 1.0).collect();
        let p = PackedBits::from_signs(&v);
        // 70 bits => second word has 6 live bits; the rest must be zero.
        assert_eq!(p.words()[1] >> 6, 0);
    }

    #[test]
    fn dot_matches_dense_dot_property() {
        check_f32_vec("packed-dot == dense-dot", 300, 1.0, |v| {
            let mut rng = Rng::new(v.len() as u64);
            let u: Vec<f32> = (0..v.len()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let pa = PackedBits::from_signs(v);
            let pb = PackedBits::from_signs(&u);
            let dense: f32 = pa
                .to_signs()
                .iter()
                .zip(pb.to_signs().iter())
                .map(|(a, b)| a * b)
                .sum();
            pa.dot_i32(&pb) == dense as i32
        });
    }

    #[test]
    fn dot_extremes() {
        let ones = PackedBits::from_signs(&vec![1.0f32; 100]);
        let negs = PackedBits::from_signs(&vec![-1.0f32; 100]);
        assert_eq!(ones.dot_i32(&ones), 100);
        assert_eq!(ones.dot_i32(&negs), -100);
    }
}
