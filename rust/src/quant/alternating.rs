//! Algorithm 2 — the paper's **alternating multi-bit quantization**.
//!
//! Greedy initialization (Eq. 4), then `T` alternating cycles of
//! (a) least-squares refit of the coefficients with codes fixed (Eq. 5) and
//! (b) optimal code re-assignment by BST with coefficients fixed
//! (Algorithm 1). Each half-step cannot increase `‖w − Σ αᵢbᵢ‖²`, so the
//! error is monotonically non-increasing — the invariant our property test
//! pins down. The paper uses `T = 2`, cheap enough to quantize activations
//! online during inference.
//!
//! Cost (paper §3): `2Tk²n` binary + `2(T+1)kn` non-binary operations.

use super::{bst, greedy, lsq, scratch::QuantScratch, Quantized};

/// k-bit alternating quantization written directly into caller-provided
/// buffers: `alphas` (length `k`) and `planes` (`k · ⌈n/64⌉` packed words,
/// layout `[plane][word]`). This is the serving hot path — the online
/// activation quantization of every timestep — fused end to end: greedy
/// init, then `t` cycles of LSQ refit + BST re-assignment, all on the same
/// packed words with no intermediate `Quantized` and no `PackedBits`
/// round-trip. Bit-identical to [`quantize`] (the allocating API is a thin
/// wrapper over this core) and allocation-free once `scratch` is warm.
pub fn quantize_into(
    w: &[f32],
    k: usize,
    t: usize,
    alphas: &mut [f32],
    planes: &mut [u64],
    scratch: &mut QuantScratch,
) {
    greedy::quantize_into(w, k, alphas, planes, scratch);
    for _ in 0..t {
        // (a) coefficients ← least squares (Eq. 5).
        lsq::refit_into(w, k, alphas, planes, scratch);
        // (b) codes ← BST assignment (Algorithm 1).
        bst::assign_into(w, alphas, planes, scratch);
    }
}

/// k-bit alternating quantization with `t` cycles (paper setting: `t = 2`).
pub fn quantize(w: &[f32], k: usize, t: usize) -> Quantized {
    let n = w.len();
    let wpp = n.div_ceil(64);
    let mut alphas = vec![0.0f32; k];
    let mut words = vec![0u64; k * wpp];
    quantize_into(w, k, t, &mut alphas, &mut words, &mut QuantScratch::default());
    Quantized { n, alphas, planes: super::planes_from_words(n, k, &words) }
}

/// Run `t` alternating cycles on an existing quantization (e.g. to continue
/// from a refined-greedy solution, or to study convergence).
pub fn alternate_in_place(w: &[f32], q: &mut Quantized, t: usize) {
    for _ in 0..t {
        // (a) coefficients ← least squares (Eq. 5).
        q.alphas = lsq::refit(w, &q.planes);
        // (b) codes ← BST assignment (Algorithm 1).
        q.planes = bst::assign(w, &q.alphas);
    }
}

/// Per-cycle squared error trace, for convergence studies (EXPERIMENTS.md):
/// entry 0 is the greedy init, entry `i` the error after cycle `i`.
pub fn error_trace(w: &[f32], k: usize, t: usize) -> Vec<f64> {
    let mut q = greedy::quantize(w, k);
    let mut trace = vec![q.sq_error(w)];
    for _ in 0..t {
        alternate_in_place(w, &mut q, 1);
        trace.push(q.sq_error(w));
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{refined, relative_mse};
    use crate::util::prop::check_f32_vec;
    use crate::util::Rng;

    #[test]
    fn error_monotone_in_cycles_property() {
        check_f32_vec("alternating-monotone-T", 300, 1.5, |w| {
            let trace = error_trace(w, 2, 4);
            trace.windows(2).all(|p| p[1] <= p[0] + 1e-6 * (1.0 + p[0]))
        });
    }

    #[test]
    fn beats_refined_on_gaussian_weights() {
        let w = Rng::new(41).normal_vec(8192, 0.1);
        for k in 2..=4 {
            let alt = relative_mse(&w, &quantize(&w, k, 2).dequantize());
            let rf = relative_mse(&w, &refined::quantize(&w, k).dequantize());
            assert!(alt <= rf + 1e-6, "k={k} alt={alt} refined={rf}");
        }
    }

    #[test]
    fn two_cycles_near_converged() {
        // Paper claim: T = 2 reaches high precision; further cycles gain little.
        let w = Rng::new(42).normal_vec(4096, 0.2);
        let trace = error_trace(&w, 2, 6);
        let gain_2 = (trace[0] - trace[2]) / trace[0];
        let gain_rest = (trace[2] - trace[6]) / trace[0];
        assert!(gain_2 > 0.0);
        assert!(gain_rest < 0.02, "post-T=2 gain {gain_rest} should be tiny");
    }

    #[test]
    fn zero_cycles_is_greedy() {
        let w = Rng::new(43).normal_vec(100, 1.0);
        let a = quantize(&w, 3, 0);
        let g = crate::quant::greedy::quantize(&w, 3);
        assert_eq!(a.alphas, g.alphas);
    }

    #[test]
    fn half_steps_never_increase_error_property() {
        // Finer-grained than the cycle test: refit alone and reassign alone
        // must each be non-increasing.
        check_f32_vec("alternating-half-steps", 200, 1.0, |w| {
            let mut q = crate::quant::greedy::quantize(w, 2);
            let e0 = q.sq_error(w);
            q.alphas = crate::quant::lsq::refit(w, &q.planes);
            let e1 = q.sq_error(w);
            q.planes = crate::quant::bst::assign(w, &q.alphas);
            let e2 = q.sq_error(w);
            e1 <= e0 + 1e-5 * (1.0 + e0) && e2 <= e1 + 1e-5 * (1.0 + e1)
        });
    }

    #[test]
    fn ppw_relevant_mse_band() {
        // Sanity band: on unit gaussian weights, 2-bit alternating relative
        // MSE lands near the paper's Table 1 value (~0.125 on trained LSTM
        // weights; gaussian is the standard model for those).
        let w = Rng::new(44).normal_vec(65536, 1.0);
        let e = relative_mse(&w, &quantize(&w, 2, 2).dequantize());
        assert!(e > 0.05 && e < 0.20, "2-bit relative MSE {e}");
    }
}
