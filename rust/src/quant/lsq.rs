//! Least-squares refit of the coefficients (Eq. 5 of the paper):
//! `[α₁…α_k] = (BᵀB)⁻¹ Bᵀ w` with `B = [b₁ … b_k] ∈ {−1,+1}^{n×k}`.
//!
//! `BᵀB` entries are integer dot products of binary planes, computed with
//! the same XOR/popcount identity as the inference kernels. The k×k system
//! is solved by Gaussian elimination with partial pivoting in f64; a tiny
//! ridge is added if the planes are linearly dependent (which happens when
//! two planes coincide, e.g. after aggressive re-assignment).

use super::packed::PackedBits;

/// Solve the k×k linear system `G x = c` in-place. Returns `None` when the
/// matrix is numerically singular even after pivoting.
fn solve(mut g: Vec<Vec<f64>>, mut c: Vec<f64>) -> Option<Vec<f64>> {
    let k = c.len();
    for col in 0..k {
        // Partial pivot.
        let piv = (col..k).max_by(|&a, &b| g[a][col].abs().total_cmp(&g[b][col].abs()))?;
        if g[piv][col].abs() < 1e-12 {
            return None;
        }
        g.swap(col, piv);
        c.swap(col, piv);
        for row in col + 1..k {
            let f = g[row][col] / g[col][col];
            for j in col..k {
                g[row][j] -= f * g[col][j];
            }
            c[row] -= f * c[col];
        }
    }
    let mut x = vec![0.0; k];
    for row in (0..k).rev() {
        let mut s = c[row];
        for j in row + 1..k {
            s -= g[row][j] * x[j];
        }
        x[row] = s / g[row][row];
    }
    Some(x)
}

/// Refit coefficients for fixed binary planes: the exact minimizer of
/// `‖w − Σᵢ αᵢ bᵢ‖²`.
pub fn refit(w: &[f32], planes: &[PackedBits]) -> Vec<f32> {
    let k = planes.len();
    let n = w.len();
    assert!(planes.iter().all(|p| p.len() == n));
    if n == 0 {
        return vec![0.0; k];
    }

    // Gram matrix G[i][j] = <b_i, b_j> via XOR/popcount; rhs c[i] = <b_i, w>.
    let mut g = vec![vec![0.0f64; k]; k];
    for i in 0..k {
        g[i][i] = n as f64;
        for j in i + 1..k {
            let d = planes[i].dot_i32(&planes[j]) as f64;
            g[i][j] = d;
            g[j][i] = d;
        }
    }
    let c: Vec<f64> = planes
        .iter()
        .map(|p| w.iter().enumerate().map(|(j, &x)| x as f64 * p.sign(j) as f64).sum())
        .collect();

    // Try the exact system; fall back to a ridge for dependent planes.
    if let Some(x) = solve(g.clone(), c.clone()) {
        if x.iter().all(|v| v.is_finite()) {
            return x.iter().map(|&v| v as f32).collect();
        }
    }
    let mut gr = g;
    for (i, row) in gr.iter_mut().enumerate() {
        row[i] += 1e-6 * n as f64;
    }
    solve(gr, c)
        .map(|x| x.iter().map(|&v| v as f32).collect())
        .unwrap_or_else(|| vec![0.0; k])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn rand_planes(rng: &mut Rng, k: usize, n: usize) -> Vec<PackedBits> {
        (0..k)
            .map(|_| {
                let signs: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                PackedBits::from_signs(&signs)
            })
            .collect()
    }

    fn residual(w: &[f32], planes: &[PackedBits], alphas: &[f32]) -> f64 {
        w.iter()
            .enumerate()
            .map(|(j, &x)| {
                let hat: f32 = planes.iter().zip(alphas).map(|(p, &a)| a * p.sign(j)).sum();
                ((x - hat) as f64).powi(2)
            })
            .sum()
    }

    #[test]
    fn exact_recovery_when_w_in_span() {
        // If w = 0.7*b1 + 0.2*b2 exactly, refit must recover (0.7, 0.2).
        let mut rng = Rng::new(21);
        let planes = rand_planes(&mut rng, 2, 333);
        let w: Vec<f32> = (0..333)
            .map(|j| 0.7 * planes[0].sign(j) + 0.2 * planes[1].sign(j))
            .collect();
        let a = refit(&w, &planes);
        assert!((a[0] - 0.7).abs() < 1e-5 && (a[1] - 0.2).abs() < 1e-5, "{a:?}");
    }

    #[test]
    fn refit_is_stationary_point_property() {
        // Property: perturbing any refit coefficient cannot reduce the
        // residual (definition of least squares).
        prop::check(
            "lsq-optimal",
            prop::Config { cases: 100, ..Default::default() },
            |rng| {
                let k = 1 + rng.below(4);
                let n = 8 + rng.below(120);
                let planes = rand_planes(rng, k, n);
                let w: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                (w, planes)
            },
            |_| vec![],
            |(w, planes)| {
                let a = refit(w, planes);
                let base = residual(w, planes, &a);
                (0..a.len()).all(|i| {
                    [-1e-3f32, 1e-3].iter().all(|&d| {
                        let mut ap = a.clone();
                        ap[i] += d;
                        residual(w, planes, &ap) >= base - 1e-6 * (1.0 + base)
                    })
                })
            },
        );
    }

    #[test]
    fn dependent_planes_do_not_explode() {
        // Two identical planes: Gram is singular; ridge fallback must give
        // finite coefficients with near-optimal residual.
        let mut rng = Rng::new(22);
        let p = rand_planes(&mut rng, 1, 100).pop().unwrap();
        let planes = vec![p.clone(), p];
        let w: Vec<f32> = (0..100).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let a = refit(&w, &planes);
        assert!(a.iter().all(|v| v.is_finite()));
        // Combined coefficient should approximate the k=1 optimum.
        let single = refit(&w, &planes[..1]);
        assert!((a[0] + a[1] - single[0]).abs() < 1e-2, "{a:?} vs {single:?}");
    }

    #[test]
    fn k1_refit_is_mean_of_signed_values() {
        // For k=1: α = <b, w>/n.
        let w = [0.5f32, -1.5, 2.0, -0.25];
        let plane = PackedBits::from_signs(&w);
        let a = refit(&w, std::slice::from_ref(&plane));
        let expect: f32 = w.iter().map(|x| x.abs()).sum::<f32>() / 4.0;
        assert!((a[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn empty_input() {
        let planes = vec![PackedBits::zeros(0); 2];
        let a = refit(&[], &planes);
        assert_eq!(a, vec![0.0, 0.0]);
    }
}
