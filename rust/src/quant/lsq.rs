//! Least-squares refit of the coefficients (Eq. 5 of the paper):
//! `[α₁…α_k] = (BᵀB)⁻¹ Bᵀ w` with `B = [b₁ … b_k] ∈ {−1,+1}^{n×k}`.
//!
//! `BᵀB` entries are integer dot products of binary planes, computed with
//! the same XOR/popcount identity as the inference kernels. The k×k system
//! is solved by Gaussian elimination with partial pivoting in f64; a tiny
//! ridge is added if the planes are linearly dependent (which happens when
//! two planes coincide, e.g. after aggressive re-assignment).

use super::packed::PackedBits;
use super::scratch::QuantScratch;

/// Solve the k×k linear system `G x = c` in place over flat row-major
/// storage. Returns `false` when the matrix is numerically singular even
/// after pivoting. Identical arithmetic (and pivot tie behavior) to the
/// boxed `Vec<Vec<f64>>` solver it replaces — rows swap by value instead of
/// by pointer, which changes nothing the elimination sees.
fn solve_in_place(k: usize, g: &mut [f64], c: &mut [f64], x: &mut [f64]) -> bool {
    for col in 0..k {
        // Partial pivot. `is_ge` keeps the LAST maximum on ties, matching
        // the old `Iterator::max_by` selection exactly.
        let mut piv = col;
        for row in col + 1..k {
            if g[row * k + col].abs().total_cmp(&g[piv * k + col].abs()).is_ge() {
                piv = row;
            }
        }
        if g[piv * k + col].abs() < 1e-12 {
            return false;
        }
        if piv != col {
            for j in 0..k {
                g.swap(col * k + j, piv * k + j);
            }
            c.swap(col, piv);
        }
        for row in col + 1..k {
            let f = g[row * k + col] / g[col * k + col];
            for j in col..k {
                g[row * k + j] -= f * g[col * k + j];
            }
            c[row] -= f * c[col];
        }
    }
    for row in (0..k).rev() {
        let mut s = c[row];
        for j in row + 1..k {
            s -= g[row * k + j] * x[j];
        }
        x[row] = s / g[row * k + row];
    }
    true
}

/// [`refit`] over contiguous packed planes (`k · ⌈n/64⌉` words, layout
/// `[plane][word]`), writing the coefficients into `alphas` (length `k`).
/// Bit-identical to [`refit`] — the allocating API is a thin wrapper over
/// this core — and allocation-free once `scratch` is warm.
///
/// Precondition: tail bits beyond `n` in every plane word must be zero
/// (the invariant `PackedBits` enforces, and which `greedy`/`bst` `_into`
/// writers maintain by zeroing whole words) — the Gram loop XORs full
/// words, so nonzero pads would silently corrupt the dot products.
pub fn refit_into(
    w: &[f32],
    k: usize,
    alphas: &mut [f32],
    planes: &[u64],
    scratch: &mut QuantScratch,
) {
    let n = w.len();
    let wpp = n.div_ceil(64);
    assert_eq!(alphas.len(), k, "alpha buffer size mismatch");
    assert_eq!(planes.len(), k * wpp, "plane buffer size mismatch");
    if n % 64 != 0 {
        for t in 0..k {
            debug_assert_eq!(
                planes[(t + 1) * wpp - 1] >> (n % 64),
                0,
                "tail bits beyond n={n} must be zero (plane {t})"
            );
        }
    }
    if n == 0 {
        alphas.fill(0.0);
        return;
    }

    // Gram matrix G[i][j] = <b_i, b_j> via XOR/popcount; rhs c[i] = <b_i, w>.
    scratch.gram.clear();
    scratch.gram.resize(k * k, 0.0);
    for i in 0..k {
        scratch.gram[i * k + i] = n as f64;
        for j in i + 1..k {
            let mut mismatches = 0u32;
            for wi in 0..wpp {
                mismatches += (planes[i * wpp + wi] ^ planes[j * wpp + wi]).count_ones();
            }
            let d = (n as i32 - 2 * mismatches as i32) as f64;
            scratch.gram[i * k + j] = d;
            scratch.gram[j * k + i] = d;
        }
    }
    scratch.rhs.clear();
    scratch.rhs.resize(k, 0.0);
    for i in 0..k {
        let p = &planes[i * wpp..(i + 1) * wpp];
        let mut acc = 0.0f64;
        for (j, &x) in w.iter().enumerate() {
            let sign = if (p[j / 64] >> (j % 64)) & 1 == 1 { 1.0f64 } else { -1.0f64 };
            acc += x as f64 * sign;
        }
        scratch.rhs[i] = acc;
    }

    scratch.sol.clear();
    scratch.sol.resize(k, 0.0);

    // Try the exact system; fall back to a ridge for dependent planes.
    scratch.gram_w.clear();
    scratch.gram_w.extend_from_slice(&scratch.gram);
    scratch.rhs_w.clear();
    scratch.rhs_w.extend_from_slice(&scratch.rhs);
    if solve_in_place(k, &mut scratch.gram_w, &mut scratch.rhs_w, &mut scratch.sol)
        && scratch.sol.iter().all(|v| v.is_finite())
    {
        for (a, &v) in alphas.iter_mut().zip(&scratch.sol) {
            *a = v as f32;
        }
        return;
    }
    scratch.gram_w.clear();
    scratch.gram_w.extend_from_slice(&scratch.gram);
    for i in 0..k {
        scratch.gram_w[i * k + i] += 1e-6 * n as f64;
    }
    scratch.rhs_w.clear();
    scratch.rhs_w.extend_from_slice(&scratch.rhs);
    if solve_in_place(k, &mut scratch.gram_w, &mut scratch.rhs_w, &mut scratch.sol) {
        for (a, &v) in alphas.iter_mut().zip(&scratch.sol) {
            *a = v as f32;
        }
    } else {
        alphas.fill(0.0);
    }
}

/// Refit coefficients for fixed binary planes: the exact minimizer of
/// `‖w − Σᵢ αᵢ bᵢ‖²`.
pub fn refit(w: &[f32], planes: &[PackedBits]) -> Vec<f32> {
    let k = planes.len();
    let n = w.len();
    assert!(planes.iter().all(|p| p.len() == n));
    let wpp = n.div_ceil(64);
    let mut words = vec![0u64; k * wpp];
    for (t, p) in planes.iter().enumerate() {
        words[t * wpp..(t + 1) * wpp].copy_from_slice(p.words());
    }
    let mut alphas = vec![0.0f32; k];
    refit_into(w, k, &mut alphas, &words, &mut QuantScratch::default());
    alphas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn rand_planes(rng: &mut Rng, k: usize, n: usize) -> Vec<PackedBits> {
        (0..k)
            .map(|_| {
                let signs: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                PackedBits::from_signs(&signs)
            })
            .collect()
    }

    fn residual(w: &[f32], planes: &[PackedBits], alphas: &[f32]) -> f64 {
        w.iter()
            .enumerate()
            .map(|(j, &x)| {
                let hat: f32 = planes.iter().zip(alphas).map(|(p, &a)| a * p.sign(j)).sum();
                ((x - hat) as f64).powi(2)
            })
            .sum()
    }

    #[test]
    fn exact_recovery_when_w_in_span() {
        // If w = 0.7*b1 + 0.2*b2 exactly, refit must recover (0.7, 0.2).
        let mut rng = Rng::new(21);
        let planes = rand_planes(&mut rng, 2, 333);
        let w: Vec<f32> = (0..333)
            .map(|j| 0.7 * planes[0].sign(j) + 0.2 * planes[1].sign(j))
            .collect();
        let a = refit(&w, &planes);
        assert!((a[0] - 0.7).abs() < 1e-5 && (a[1] - 0.2).abs() < 1e-5, "{a:?}");
    }

    #[test]
    fn refit_is_stationary_point_property() {
        // Property: perturbing any refit coefficient cannot reduce the
        // residual (definition of least squares).
        prop::check(
            "lsq-optimal",
            prop::Config { cases: 100, ..Default::default() },
            |rng| {
                let k = 1 + rng.below(4);
                let n = 8 + rng.below(120);
                let planes = rand_planes(rng, k, n);
                let w: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                (w, planes)
            },
            |_| vec![],
            |(w, planes)| {
                let a = refit(w, planes);
                let base = residual(w, planes, &a);
                (0..a.len()).all(|i| {
                    [-1e-3f32, 1e-3].iter().all(|&d| {
                        let mut ap = a.clone();
                        ap[i] += d;
                        residual(w, planes, &ap) >= base - 1e-6 * (1.0 + base)
                    })
                })
            },
        );
    }

    #[test]
    fn dependent_planes_do_not_explode() {
        // Two identical planes: Gram is singular; ridge fallback must give
        // finite coefficients with near-optimal residual.
        let mut rng = Rng::new(22);
        let p = rand_planes(&mut rng, 1, 100).pop().unwrap();
        let planes = vec![p.clone(), p];
        let w: Vec<f32> = (0..100).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let a = refit(&w, &planes);
        assert!(a.iter().all(|v| v.is_finite()));
        // Combined coefficient should approximate the k=1 optimum.
        let single = refit(&w, &planes[..1]);
        assert!((a[0] + a[1] - single[0]).abs() < 1e-2, "{a:?} vs {single:?}");
    }

    #[test]
    fn k1_refit_is_mean_of_signed_values() {
        // For k=1: α = <b, w>/n.
        let w = [0.5f32, -1.5, 2.0, -0.25];
        let plane = PackedBits::from_signs(&w);
        let a = refit(&w, std::slice::from_ref(&plane));
        let expect: f32 = w.iter().map(|x| x.abs()).sum::<f32>() / 4.0;
        assert!((a[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn empty_input() {
        let planes = vec![PackedBits::zeros(0); 2];
        let a = refit(&[], &planes);
        assert_eq!(a, vec![0.0, 0.0]);
    }
}
