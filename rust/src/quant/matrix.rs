//! Row-wise quantized matrices.
//!
//! The paper quantizes weight matrices **row by row** (§4, Fig. 3 left):
//! each row gets its own `k` coefficients and `k` binary planes, adding
//! little computation while greatly improving the approximation. This type
//! is the weight-side operand of the binary GEMV kernels.

use std::sync::Mutex;

use super::{quantize, Method, PackedBits, Quantized};
use crate::exec::Exec;

/// A `rows × cols` matrix quantized row-by-row to `k` bits.
#[derive(Clone, Debug)]
pub struct RowQuantized {
    pub rows: usize,
    pub cols: usize,
    pub k: usize,
    /// `rows * k` coefficients, row-major: `alphas[r*k + i]` = αᵢ of row `r`.
    pub alphas: Vec<f32>,
    /// `rows * k` planes, row-major: `planes[r*k + i]` = bᵢ of row `r`.
    pub planes: Vec<PackedBits>,
}

impl RowQuantized {
    /// Quantize a dense row-major `rows × cols` matrix.
    pub fn quantize(w: &[f32], rows: usize, cols: usize, k: usize, method: Method) -> Self {
        Self::quantize_exec(w, rows, cols, k, method, &Exec::serial())
    }

    /// [`Self::quantize`] on an execution engine. Rows are quantized
    /// independently (the point of row-wise coefficients), so disjoint row
    /// ranges shard across workers and are stitched back in row order —
    /// bit-identical to the serial path for any thread count.
    pub fn quantize_exec(
        w: &[f32],
        rows: usize,
        cols: usize,
        k: usize,
        method: Method,
        exec: &Exec,
    ) -> Self {
        assert_eq!(w.len(), rows * cols, "matrix shape mismatch");
        let kk = if matches!(method, Method::Ternary) { 2 } else { k };
        if !exec.is_parallel() {
            let mut alphas = Vec::with_capacity(rows * kk);
            let mut planes = Vec::with_capacity(rows * kk);
            for r in 0..rows {
                let q = quantize(&w[r * cols..(r + 1) * cols], k, method);
                alphas.extend_from_slice(&q.alphas);
                planes.extend(q.planes);
            }
            return RowQuantized { rows, cols, k: kk, alphas, planes };
        }
        // Parallel: quantize disjoint row ranges, then stitch in row order.
        let chunks: Mutex<Vec<(usize, Vec<Quantized>)>> = Mutex::new(Vec::new());
        exec.run_chunks(rows, 1, &|r0, r1| {
            let part: Vec<Quantized> =
                (r0..r1).map(|r| quantize(&w[r * cols..(r + 1) * cols], k, method)).collect();
            chunks.lock().unwrap().push((r0, part));
        });
        let mut chunks = chunks.into_inner().unwrap();
        chunks.sort_unstable_by_key(|c| c.0);
        let mut alphas = Vec::with_capacity(rows * kk);
        let mut planes = Vec::with_capacity(rows * kk);
        for (_, part) in chunks {
            for q in part {
                debug_assert_eq!(q.k(), kk);
                alphas.extend_from_slice(&q.alphas);
                planes.extend(q.planes);
            }
        }
        RowQuantized { rows, cols, k: kk, alphas, planes }
    }

    /// Reassemble from the flat buffers the `.amqz` format stores: `words`
    /// is the planes' bit data concatenated row-major (`[row][plane][word]`,
    /// `cols.div_ceil(64)` words per plane — the same contiguous layout
    /// [`crate::kernels::binary::PreparedGemm`] serves from). No
    /// quantization happens; only shape and tail-bit invariants are
    /// checked, so a corrupt file reports an error instead of tripping the
    /// `PackedBits::from_words` assertions.
    pub fn from_raw_parts(
        rows: usize,
        cols: usize,
        k: usize,
        alphas: Vec<f32>,
        words: &[u64],
    ) -> Result<Self, String> {
        if rows == 0 || cols == 0 || k == 0 {
            return Err(format!("degenerate matrix shape {rows}x{cols} k={k}"));
        }
        let wpp = cols.div_ceil(64);
        let nplanes = rows
            .checked_mul(k)
            .ok_or_else(|| format!("matrix shape {rows}x{cols} k={k} overflows"))?;
        if alphas.len() != nplanes {
            return Err(format!("expected {nplanes} alphas, got {}", alphas.len()));
        }
        let nwords = nplanes
            .checked_mul(wpp)
            .ok_or_else(|| format!("matrix shape {rows}x{cols} k={k} overflows"))?;
        if words.len() != nwords {
            return Err(format!("expected {nwords} plane words, got {}", words.len()));
        }
        let mut planes = Vec::with_capacity(nplanes);
        for (p, chunk) in words.chunks_exact(wpp).enumerate() {
            if cols % 64 != 0 && chunk[wpp - 1] >> (cols % 64) != 0 {
                return Err(format!("plane {p} has nonzero bits past column {cols}"));
            }
            planes.push(PackedBits::from_words(cols, chunk.to_vec()));
        }
        Ok(RowQuantized { rows, cols, k, alphas, planes })
    }

    /// The quantization of row `r` as a standalone [`Quantized`].
    pub fn row(&self, r: usize) -> Quantized {
        Quantized {
            n: self.cols,
            alphas: self.alphas[r * self.k..(r + 1) * self.k].to_vec(),
            planes: self.planes[r * self.k..(r + 1) * self.k].to_vec(),
        }
    }

    /// Dense reconstruction (row-major).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let row = self.row(r).dequantize();
            out[r * self.cols..(r + 1) * self.cols].copy_from_slice(&row);
        }
        out
    }

    /// Total relative MSE against the original matrix — what Tables 1–2
    /// report per weight matrix.
    pub fn relative_mse(&self, w: &[f32]) -> f64 {
        super::relative_mse(w, &self.dequantize())
    }

    /// Memory footprint in bytes of the quantized representation
    /// (packed planes + f32 coefficients), used for the paper's
    /// memory-saving claims (~16× at 2 bits, ~10.5× at 3 bits).
    pub fn packed_bytes(&self) -> usize {
        let plane_bytes = self.cols.div_ceil(64) * 8;
        self.rows * self.k * (plane_bytes + 4)
    }

    /// Footprint of the dense f32 original.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Compression ratio dense/packed.
    pub fn compression(&self) -> f64 {
        self.dense_bytes() as f64 / self.packed_bytes() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::relative_mse as rmse;
    use crate::util::Rng;

    fn matrix(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(rows * cols, 0.2)
    }

    #[test]
    fn rowwise_beats_whole_matrix_quantization() {
        // The point of row-wise coefficients: give each row its own scale.
        // Build a matrix whose rows have very different scales.
        let mut rng = Rng::new(81);
        let (rows, cols) = (16, 256);
        let mut w = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let scale = 0.05 + 0.3 * r as f32;
            w.extend(rng.normal_vec(cols, scale));
        }
        let rq = RowQuantized::quantize(&w, rows, cols, 2, Method::Alternating { t: 2 });
        let whole = quantize(&w, 2, Method::Alternating { t: 2 });
        let e_row = rq.relative_mse(&w);
        let e_whole = rmse(&w, &whole.dequantize());
        assert!(e_row < e_whole, "row {e_row} vs whole {e_whole}");
    }

    #[test]
    fn row_roundtrip() {
        let w = matrix(8, 64, 82);
        let rq = RowQuantized::quantize(&w, 8, 64, 3, Method::Greedy);
        let d = rq.dequantize();
        for r in 0..8 {
            let qr = rq.row(r).dequantize();
            assert_eq!(&d[r * 64..(r + 1) * 64], &qr[..]);
        }
    }

    #[test]
    fn compression_ratio_matches_paper_ballpark() {
        // Paper: ~16× memory saving at 2 bits, ~10.5× at 3 bits (the
        // coefficients + packing overhead keep it below the ideal 32/k).
        let w = matrix(4096, 1024, 83);
        let q2 = RowQuantized::quantize(&w, 4096, 1024, 2, Method::Greedy);
        let q3 = RowQuantized::quantize(&w, 4096, 1024, 3, Method::Greedy);
        let c2 = q2.compression();
        let c3 = q3.compression();
        assert!((14.0..=16.5).contains(&c2), "2-bit compression {c2}");
        assert!((9.0..=11.0).contains(&c3), "3-bit compression {c3}");
    }

    #[test]
    fn ternary_forces_two_planes() {
        let w = matrix(4, 32, 84);
        let rq = RowQuantized::quantize(&w, 4, 32, 7, Method::Ternary);
        assert_eq!(rq.k, 2);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        RowQuantized::quantize(&[0.0; 10], 3, 4, 2, Method::Greedy);
    }
}
