//! The paper's learning-rate schedule (§5, verbatim): "The initial learning
//! rate is set to 20. Every epoch we evaluate on the validation dataset and
//! record the best value. When the validation error exceeds the best record,
//! we decrease learning rate by a factor of 1.2. Training is terminated once
//! the learning rate is less than 0.001 or reaching the maximum epochs,
//! i.e., 80."

/// What the driver should do after an epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleAction {
    Continue,
    Stop,
}

/// Validation-driven decay schedule.
#[derive(Clone, Debug)]
pub struct SgdSchedule {
    pub lr: f64,
    pub decay: f64,
    pub min_lr: f64,
    pub max_epochs: usize,
    pub epoch: usize,
    best_val: f64,
    pub best_epoch: usize,
}

impl SgdSchedule {
    /// The paper's setting.
    pub fn paper() -> Self {
        Self::new(20.0, 1.2, 1e-3, 80)
    }

    pub fn new(lr: f64, decay: f64, min_lr: f64, max_epochs: usize) -> Self {
        assert!(lr > 0.0 && decay > 1.0);
        SgdSchedule { lr, decay, min_lr, max_epochs, epoch: 0, best_val: f64::INFINITY, best_epoch: 0 }
    }

    /// Report a validation metric (lower is better). Updates lr and returns
    /// whether to continue.
    pub fn on_epoch(&mut self, val: f64) -> ScheduleAction {
        self.epoch += 1;
        if val < self.best_val {
            self.best_val = val;
            self.best_epoch = self.epoch;
        } else {
            self.lr /= self.decay;
        }
        if self.lr < self.min_lr || self.epoch >= self.max_epochs {
            ScheduleAction::Stop
        } else {
            ScheduleAction::Continue
        }
    }

    pub fn best(&self) -> f64 {
        self.best_val
    }
}

/// Gradient-norm clipping to `[-clip, clip]` (paper: 0.25). Returns the
/// pre-clip norm.
pub fn clip_gradients(grads: &mut [f32], clip: f32) -> f32 {
    let norm = grads.iter().map(|&g| (g as f64).powi(2)).sum::<f64>().sqrt() as f32;
    if norm > clip && norm > 0.0 {
        let scale = clip / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decays_only_on_regression() {
        let mut s = SgdSchedule::paper();
        assert_eq!(s.on_epoch(100.0), ScheduleAction::Continue);
        assert_eq!(s.lr, 20.0);
        assert_eq!(s.on_epoch(90.0), ScheduleAction::Continue);
        assert_eq!(s.lr, 20.0);
        s.on_epoch(95.0); // worse than best (90) → decay
        assert!((s.lr - 20.0 / 1.2).abs() < 1e-9);
        assert_eq!(s.best(), 90.0);
        assert_eq!(s.best_epoch, 2);
    }

    #[test]
    fn stops_at_min_lr() {
        let mut s = SgdSchedule::new(0.0015, 1.2, 1e-3, 1000);
        let mut action = ScheduleAction::Continue;
        let mut epochs = 0;
        while action == ScheduleAction::Continue && epochs < 100 {
            action = s.on_epoch(1.0 + epochs as f64); // always regressing
            epochs += 1;
        }
        assert_eq!(action, ScheduleAction::Stop);
        assert!(s.lr < 1e-3);
        assert!(epochs <= 4, "0.0015/1.2^3 < 0.001");
    }

    #[test]
    fn stops_at_max_epochs() {
        let mut s = SgdSchedule::new(20.0, 1.2, 1e-3, 3);
        assert_eq!(s.on_epoch(10.0), ScheduleAction::Continue);
        assert_eq!(s.on_epoch(9.0), ScheduleAction::Continue);
        assert_eq!(s.on_epoch(8.0), ScheduleAction::Stop);
    }

    #[test]
    fn clip_scales_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let pre = clip_gradients(&mut g, 0.25);
        assert!((pre - 5.0).abs() < 1e-6);
        let post = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((post - 0.25).abs() < 1e-6);
        // Under the clip: untouched.
        let mut g2 = vec![0.1f32, 0.1];
        clip_gradients(&mut g2, 0.25);
        assert_eq!(g2, vec![0.1, 0.1]);
    }
}
