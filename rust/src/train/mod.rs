//! Training drivers.
//!
//! * [`schedule`] — the paper's exact §5 SGD schedule: lr starts at 20,
//!   divides by 1.2 whenever validation PPW regresses past the best seen,
//!   stops below lr 0.001 or at 80 epochs; gradient clip 0.25, unroll 30,
//!   dropout 0.5.
//! * [`trainer`] — the Layer-3 loop that drives the AOT-compiled Layer-2
//!   `train_step` / `eval_step` artifacts through the PJRT runtime,
//!   carrying recurrent state across BPTT windows and checkpointing in the
//!   shared named-tensor format.
//! * [`native`] — pure-Rust STE trainers for the Appendix-B image tables
//!   (MLP on MNIST-like, CNN on CIFAR-like, LSTM on sequential MNIST-like).

pub mod native;
pub mod schedule;
pub mod trainer;

pub use schedule::{SgdSchedule, ScheduleAction};
pub use trainer::{LmTrainer, TrainReport};
