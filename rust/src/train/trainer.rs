//! The Layer-3 training loop over AOT-compiled Layer-2 artifacts.
//!
//! Contract with `python/compile/aot.py` (one variant = one `<tag>`):
//!
//! * `artifacts/<tag>.manifest.txt` — metadata + ordered parameter list:
//!   ```text
//!   kind lstm | gru
//!   vocab 2000
//!   hidden 200
//!   batch 20
//!   bptt 30
//!   param embedding 2000,200
//!   param wx 800,200
//!   ...
//!   ```
//! * `artifacts/<tag>_init.amqt` — initial parameters (named tensors).
//! * `artifacts/<tag>_train.hlo.txt` — one SGD step:
//!   `(params…, h0, c0, x, y, lr) → (params'…, h', c', mean_nll)`
//!   (GRU variants omit `c0`/`c'`).
//! * `artifacts/<tag>_eval.hlo.txt` — forward only:
//!   `(params…, h0, c0, x, y) → (h', c', sum_nll, count)`.
//!
//! The loop carries recurrent state across BPTT windows within an epoch
//! (standard contiguous training) and applies the §5 schedule.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::batcher::LmBatcher;
use crate::data::checkpoint::Checkpoint;
use crate::model::{LmConfig, RnnKind};
use crate::runtime::{Arg, Engine, HostTensor, HostTokens};
use crate::train::schedule::{ScheduleAction, SgdSchedule};

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub kind: RnnKind,
    pub vocab: usize,
    pub hidden: usize,
    pub batch: usize,
    pub bptt: usize,
    /// Ordered (name, shape) — artifact argument order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut kind = None;
        let (mut vocab, mut hidden, mut batch, mut bptt) = (0, 0, 0, 0);
        let mut params = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            match it.next().unwrap_or("") {
                "kind" => {
                    kind = Some(match it.next().unwrap_or("") {
                        "lstm" => RnnKind::Lstm,
                        "gru" => RnnKind::Gru,
                        other => bail!("manifest: unknown kind '{other}'"),
                    })
                }
                "vocab" => vocab = it.next().unwrap_or("0").parse()?,
                "hidden" => hidden = it.next().unwrap_or("0").parse()?,
                "batch" => batch = it.next().unwrap_or("0").parse()?,
                "bptt" => bptt = it.next().unwrap_or("0").parse()?,
                "param" => {
                    let name = it.next().context("param name")?.to_string();
                    let shape: Vec<usize> = it
                        .next()
                        .context("param shape")?
                        .split(',')
                        .map(|d| d.parse::<usize>())
                        .collect::<std::result::Result<_, _>>()?;
                    params.push((name, shape));
                }
                other => bail!("manifest: unknown directive '{other}'"),
            }
        }
        if vocab == 0 || hidden == 0 || batch == 0 || bptt == 0 || params.is_empty() {
            bail!("manifest incomplete");
        }
        Ok(Manifest { kind: kind.context("manifest missing kind")?, vocab, hidden, batch, bptt, params })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?)
    }

    pub fn lm_config(&self) -> LmConfig {
        LmConfig { kind: self.kind, vocab: self.vocab, hidden: self.hidden, layers: 1 }
    }
}

/// Per-epoch training record.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub epoch_losses: Vec<f64>,
    pub val_ppws: Vec<f64>,
    pub best_val_ppw: f64,
    pub steps: usize,
}

/// The driver.
pub struct LmTrainer {
    pub manifest: Manifest,
    pub tag: String,
    engine: Engine,
    /// Current parameters, in manifest order.
    pub params: Vec<HostTensor>,
}

impl LmTrainer {
    /// Load manifest + artifacts + initial params for `<tag>`.
    pub fn load(artifact_dir: impl Into<PathBuf>, tag: &str) -> Result<Self> {
        let dir: PathBuf = artifact_dir.into();
        let manifest = Manifest::load(&dir.join(format!("{tag}.manifest.txt")))?;
        let mut engine = Engine::cpu(&dir)?;
        engine.load(&format!("{tag}_train"))?;
        engine.load(&format!("{tag}_eval"))?;
        let init = Checkpoint::load(&dir.join(format!("{tag}_init.amqt")))?;
        let params = manifest
            .params
            .iter()
            .map(|(name, shape)| {
                let t = init.get(name)?;
                if &t.shape != shape {
                    bail!("init param '{name}' shape {:?} != manifest {:?}", t.shape, shape);
                }
                Ok(HostTensor::new(t.shape.clone(), t.data.clone()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LmTrainer { manifest, tag: tag.to_string(), engine, params })
    }

    fn state_tensors(&self) -> Vec<HostTensor> {
        let (b, h) = (self.manifest.batch, self.manifest.hidden);
        let zero = HostTensor::new(vec![b, h], vec![0.0; b * h]);
        match self.manifest.kind {
            RnnKind::Lstm => vec![zero.clone(), zero],
            RnnKind::Gru => vec![zero],
        }
    }

    fn tokens(&self, xs: &[usize], len: usize) -> HostTokens {
        HostTokens::new(vec![self.manifest.batch, len], xs.iter().map(|&t| t as i32).collect())
    }

    /// One epoch of SGD over `train`; returns mean per-token NLL.
    pub fn train_epoch(&mut self, train: &[usize], lr: f32, max_steps: Option<usize>) -> Result<(f64, usize)> {
        let mut batcher = LmBatcher::new(train, self.manifest.batch, self.manifest.bptt);
        let mut state = self.state_tensors();
        let mut total_loss = 0.0f64;
        let mut steps = 0usize;
        let lr_t = HostTensor::scalar(lr);
        while let Some((x, y, len)) = batcher.next() {
            if len != self.manifest.bptt {
                break; // graphs are fixed-shape; drop the ragged tail window
            }
            let xt = self.tokens(&x, len);
            let yt = self.tokens(&y, len);
            let mut args: Vec<Arg<'_>> = self.params.iter().map(Arg::F32).collect();
            for s in &state {
                args.push(Arg::F32(s));
            }
            args.push(Arg::I32(&xt));
            args.push(Arg::I32(&yt));
            args.push(Arg::F32(&lr_t));
            let out = self.engine.execute(&format!("{}_train", self.tag), &args)?;
            let np = self.params.len();
            let ns = state.len();
            if out.len() != np + ns + 1 {
                bail!("train artifact returned {} outputs, expected {}", out.len(), np + ns + 1);
            }
            self.params = out[..np].to_vec();
            state = out[np..np + ns].to_vec();
            total_loss += out[np + ns].data[0] as f64;
            steps += 1;
            if let Some(ms) = max_steps {
                if steps >= ms {
                    break;
                }
            }
        }
        if steps == 0 {
            bail!("no full windows in corpus");
        }
        Ok((total_loss / steps as f64, steps))
    }

    /// PPW on a token stream via the eval artifact.
    pub fn evaluate(&mut self, tokens: &[usize], max_steps: Option<usize>) -> Result<f64> {
        let mut batcher = LmBatcher::new(tokens, self.manifest.batch, self.manifest.bptt);
        let mut state = self.state_tensors();
        let (mut nll, mut count) = (0.0f64, 0.0f64);
        let mut steps = 0usize;
        while let Some((x, y, len)) = batcher.next() {
            if len != self.manifest.bptt {
                break;
            }
            let xt = self.tokens(&x, len);
            let yt = self.tokens(&y, len);
            let mut args: Vec<Arg<'_>> = self.params.iter().map(Arg::F32).collect();
            for s in &state {
                args.push(Arg::F32(s));
            }
            args.push(Arg::I32(&xt));
            args.push(Arg::I32(&yt));
            let out = self.engine.execute(&format!("{}_eval", self.tag), &args)?;
            let ns = state.len();
            state = out[..ns].to_vec();
            nll += out[ns].data[0] as f64;
            count += out[ns + 1].data[0] as f64;
            steps += 1;
            if let Some(ms) = max_steps {
                if steps >= ms {
                    break;
                }
            }
        }
        if count == 0.0 {
            bail!("empty evaluation");
        }
        Ok((nll / count).exp())
    }

    /// Full schedule-driven run (step-budgeted for CPU: `steps_per_epoch`
    /// and `epochs` bound the work; the schedule may stop earlier).
    pub fn fit(
        &mut self,
        train: &[usize],
        valid: &[usize],
        mut schedule: SgdSchedule,
        epochs: usize,
        steps_per_epoch: Option<usize>,
        eval_steps: Option<usize>,
        mut log: impl FnMut(usize, f64, f64, f64),
    ) -> Result<TrainReport> {
        let mut report = TrainReport {
            epoch_losses: Vec::new(),
            val_ppws: Vec::new(),
            best_val_ppw: f64::INFINITY,
            steps: 0,
        };
        for epoch in 0..epochs {
            let (loss, steps) = self.train_epoch(train, schedule.lr as f32, steps_per_epoch)?;
            let val = self.evaluate(valid, eval_steps)?;
            report.epoch_losses.push(loss);
            report.val_ppws.push(val);
            report.best_val_ppw = report.best_val_ppw.min(val);
            report.steps += steps;
            log(epoch, loss, val, schedule.lr);
            if schedule.on_epoch(val) == ScheduleAction::Stop {
                break;
            }
        }
        Ok(report)
    }

    /// Snapshot current params as a checkpoint.
    pub fn checkpoint(&self) -> Checkpoint {
        let mut c = Checkpoint::new();
        for ((name, _), t) in self.manifest.params.iter().zip(&self.params) {
            c.insert(name, t.shape.clone(), t.data.clone());
        }
        c
    }
}

/// Convert a trained checkpoint into dense [`crate::model::lm::LmWeights`]
/// for the native inference engine (name contract with aot.py).
pub fn weights_from_checkpoint(
    ckpt: &Checkpoint,
    config: &LmConfig,
) -> Result<crate::model::lm::LmWeights> {
    let get = |name: &str| -> Result<Vec<f32>> { Ok(ckpt.get(name)?.data.clone()) };
    Ok(crate::model::lm::LmWeights {
        embedding: get("embedding")?,
        wx: vec![get("wx")?],
        wh: vec![get("wh")?],
        bias: vec![get("bias")?],
        softmax_w: get("softmax_w")?,
        softmax_b: get("softmax_b")?,
        // layers fixed at 1, matching the paper's models.
    })
    .and_then(|w| {
        let g = config.kind.gates();
        if w.wx[0].len() != g * config.hidden * config.hidden {
            bail!(
                "checkpoint wx size {} != expected {} (kind/hidden mismatch)",
                w.wx[0].len(),
                g * config.hidden * config.hidden
            );
        }
        Ok(w)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
kind lstm
vocab 2000
hidden 200
batch 20
bptt 30
param embedding 2000,200
param wx 800,200
param wh 800,200
param bias 800
param softmax_w 2000,200
param softmax_b 2000
";

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.kind, RnnKind::Lstm);
        assert_eq!((m.vocab, m.hidden, m.batch, m.bptt), (2000, 200, 20, 30));
        assert_eq!(m.params.len(), 6);
        assert_eq!(m.params[1], ("wx".to_string(), vec![800, 200]));
        assert_eq!(m.lm_config().vocab, 2000);
    }

    #[test]
    fn manifest_rejects_incomplete_and_unknown() {
        assert!(Manifest::parse("kind lstm\n").is_err());
        assert!(Manifest::parse("bogus 1\n").is_err());
        assert!(Manifest::parse(&SAMPLE.replace("lstm", "elman")).is_err());
    }

    // End-to-end trainer tests live in rust/tests/train_e2e.rs and require
    // `make artifacts`.
}
