//! Native STE trainers for the Appendix-B image experiments.
//!
//! * [`MlpTrainer`] — Table 8: MLP with BN + L2-SVM head on MNIST-like
//!   data; quantized inputs/weights/activations via the straight-through
//!   estimator (activations re-quantized each forward pass, gradients pass
//!   through the quantizer unchanged).
//! * [`SeqLstmTrainer`] — Table 7: row-by-row sequential classification
//!   with an LSTM (28 steps of 28 pixels), quantized input/weights/
//!   activations.
//! * [`CnnTrainer`] — Table 9: the VGG-like conv net (channel-scaled for
//!   the CPU budget) with 2-bit weights / 1-bit activations.

use crate::data::images::ImageSet;
use crate::model::cnn::{maxpool2, maxpool2_backward, Conv3x3, Shape};
use crate::model::lstm::{step_dense_backward, step_dense_tape};
use crate::model::math::argmax;
use crate::model::mlp::{
    l2svm_loss, relu, ste_quantize_activations, BatchNorm, DenseLayer, QuantSpec,
};
use crate::quant::Method;
use crate::util::Rng;

/// Quantize input images in place (the paper quantizes inputs too, e.g.
/// 2-bit inputs for the MLP, 1-bit for sequential MNIST).
pub fn quantize_inputs(images: &mut [f32], n: usize, dim: usize, k: usize, method: Method) {
    ste_quantize_activations(images, n, dim, k, method);
}

// ---------------------------------------------------------------------------
// Table 8: MLP.
// ---------------------------------------------------------------------------

/// MLP trainer configuration.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub layer_sizes: Vec<usize>, // e.g. [784, 512, 512, 512, 10]
    pub spec: QuantSpec,
    pub input_bits: Option<usize>,
    pub lr: f32,
    pub batch: usize,
}

pub struct MlpTrainer {
    pub config: MlpConfig,
    layers: Vec<DenseLayer>,
    bns: Vec<BatchNorm>,
    t: usize,
}

impl MlpTrainer {
    pub fn new(config: MlpConfig, seed: u64) -> Self {
        assert!(config.layer_sizes.len() >= 2);
        let mut rng = Rng::new(seed);
        let mut layers = Vec::new();
        let mut bns = Vec::new();
        for w in config.layer_sizes.windows(2) {
            layers.push(DenseLayer::init(w[1], w[0], &mut rng));
            bns.push(BatchNorm::new(w[1]));
        }
        MlpTrainer { config, layers, bns, t: 0 }
    }

    /// One minibatch of STE training; returns the batch loss.
    pub fn train_batch(&mut self, x: &[f32], labels: &[usize]) -> f32 {
        let batch = labels.len();
        let nl = self.layers.len();
        let spec = self.config.spec;
        // Forward, keeping tapes.
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut wqs = Vec::new();
        let mut bn_tapes = Vec::new();
        let mut relu_masks = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            let wq = layer.effective_w(&spec);
            let mut y = layer.forward(&wq, acts.last().unwrap(), batch);
            wqs.push(wq);
            if li + 1 < nl {
                let (yb, tape) = self.bns[li].forward_train(&y, batch);
                y = yb;
                bn_tapes.push(tape);
                // Quantized activations REPLACE ReLU (BNN convention: sign
                // quantization of the symmetric BN output; a ReLU first
                // would collapse 1-bit codes to a constant). STE backward.
                match spec.k_a {
                    Some(ka) => {
                        ste_quantize_activations(&mut y, batch, layer.rows, ka, spec.method);
                        relu_masks.push(vec![true; y.len()]);
                    }
                    None => relu_masks.push(relu(&mut y)),
                }
            }
            acts.push(y);
        }
        let classes = *self.config.layer_sizes.last().unwrap();
        let (loss, mut dy) = l2svm_loss(acts.last().unwrap(), labels, batch, classes);
        // Backward (STE: quantizers are identity).
        self.t += 1;
        for li in (0..nl).rev() {
            let layer = &self.layers[li];
            if li + 1 < nl {
                // Through ReLU.
                for (d, &m) in dy.iter_mut().zip(&relu_masks[li]) {
                    if !m {
                        *d = 0.0;
                    }
                }
                // Through BN.
                dy = self.bns[li].backward(&bn_tapes[li], &dy, batch, self.config.lr * 0.1);
            }
            let mut gw = vec![0.0f32; layer.w.len()];
            let mut gb = vec![0.0f32; layer.b.len()];
            let dx = layer.backward(&wqs[li], &acts[li], &dy, batch, &mut gw, &mut gb);
            self.layers[li].adam_step(&gw, &gb, self.config.lr, self.t);
            dy = dx;
        }
        loss
    }

    /// Forward in eval mode; returns predicted classes.
    pub fn predict(&self, x: &[f32], batch: usize) -> Vec<usize> {
        let nl = self.layers.len();
        let spec = self.config.spec;
        let mut a = x.to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let wq = layer.effective_w(&spec);
            let mut y = layer.forward(&wq, &a, batch);
            if li + 1 < nl {
                y = self.bns[li].forward_eval(&y, batch);
                match spec.k_a {
                    Some(ka) => ste_quantize_activations(&mut y, batch, layer.rows, ka, spec.method),
                    None => {
                        relu(&mut y);
                    }
                }
            }
            a = y;
        }
        let classes = *self.config.layer_sizes.last().unwrap();
        (0..batch).map(|b| argmax(&a[b * classes..(b + 1) * classes])).collect()
    }

    /// Train for `epochs` passes, return final test error rate.
    pub fn fit(&mut self, train: &ImageSet, test: &ImageSet, epochs: usize, seed: u64) -> f64 {
        let dim = train.pixels();
        let mut train_images = train.images.clone();
        let mut test_images = test.images.clone();
        if let Some(kin) = self.config.input_bits {
            quantize_inputs(&mut train_images, train.n, dim, kin, self.config.spec.method);
            quantize_inputs(&mut test_images, test.n, dim, kin, self.config.spec.method);
        }
        let mut rng = Rng::new(seed);
        let batch = self.config.batch;
        let mut order: Vec<usize> = (0..train.n).collect();
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(batch) {
                if chunk.len() < batch {
                    break;
                }
                let mut xb = Vec::with_capacity(batch * dim);
                let mut lb = Vec::with_capacity(batch);
                for &i in chunk {
                    xb.extend_from_slice(&train_images[i * dim..(i + 1) * dim]);
                    lb.push(train.labels[i]);
                }
                self.train_batch(&xb, &lb);
            }
        }
        self.error_rate(&test_images, &test.labels, dim)
    }

    pub fn error_rate(&self, images: &[f32], labels: &[usize], dim: usize) -> f64 {
        let n = labels.len();
        let batch = 50.min(n);
        let mut wrong = 0usize;
        let mut i = 0;
        while i + batch <= n {
            let preds = self.predict(&images[i * dim..(i + batch) * dim], batch);
            for (p, &l) in preds.iter().zip(&labels[i..i + batch]) {
                if *p != l {
                    wrong += 1;
                }
            }
            i += batch;
        }
        wrong as f64 / i.max(1) as f64
    }
}

// ---------------------------------------------------------------------------
// Table 7: sequential LSTM classifier.
// ---------------------------------------------------------------------------

/// Sequential-rows LSTM classifier (image rows as timesteps).
pub struct SeqLstmTrainer {
    pub input: usize,
    pub hidden: usize,
    pub classes: usize,
    pub spec: QuantSpec,
    pub input_bits: Option<usize>,
    pub lr: f32,
    wx: Vec<f32>,
    wh: Vec<f32>,
    bias: Vec<f32>,
    head: DenseLayer,
    // Adam state for the recurrent weights.
    mwx: Vec<f32>,
    vwx: Vec<f32>,
    mwh: Vec<f32>,
    vwh: Vec<f32>,
    t: usize,
}

impl SeqLstmTrainer {
    pub fn new(input: usize, hidden: usize, classes: usize, spec: QuantSpec, input_bits: Option<usize>, lr: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let scale = (1.0 / hidden as f32).sqrt();
        SeqLstmTrainer {
            input,
            hidden,
            classes,
            spec,
            input_bits,
            lr,
            wx: rng.normal_vec(4 * hidden * input, scale),
            wh: rng.normal_vec(4 * hidden * hidden, scale),
            bias: vec![0.0; 4 * hidden],
            head: DenseLayer::init(classes, hidden, &mut rng),
            mwx: vec![0.0; 4 * hidden * input],
            vwx: vec![0.0; 4 * hidden * input],
            mwh: vec![0.0; 4 * hidden * hidden],
            vwh: vec![0.0; 4 * hidden * hidden],
            t: 0,
        }
    }

    fn effective(&self) -> (Vec<f32>, Vec<f32>) {
        match self.spec.k_w {
            Some(k) => (
                crate::model::mlp::ste_quantize_matrix(&self.wx, 4 * self.hidden, self.input, k, self.spec.method),
                crate::model::mlp::ste_quantize_matrix(&self.wh, 4 * self.hidden, self.hidden, k, self.spec.method),
            ),
            None => (self.wx.clone(), self.wh.clone()),
        }
    }

    fn quantize_h(&self, h: &mut Vec<f32>) {
        if let Some(ka) = self.spec.k_a {
            let q = crate::quant::quantize(h, ka, self.spec.method);
            *h = q.dequantize();
        }
    }

    /// Train on one image (rows = timesteps); returns loss.
    pub fn train_one(&mut self, image: &[f32], rows: usize, label: usize) -> f32 {
        let (wxq, whq) = self.effective();
        let mut hs: Vec<Vec<f32>> = vec![vec![0.0; self.hidden]];
        let mut cs: Vec<Vec<f32>> = vec![vec![0.0; self.hidden]];
        let mut tapes = Vec::new();
        let mut xs: Vec<Vec<f32>> = Vec::new();
        for r in 0..rows {
            let mut x = image[r * self.input..(r + 1) * self.input].to_vec();
            if let Some(kin) = self.input_bits {
                let q = crate::quant::quantize(&x, kin, self.spec.method);
                x = q.dequantize();
            }
            let tape = step_dense_tape(
                &wxq, &whq, &self.bias, self.input, self.hidden,
                &x, hs.last().unwrap(), cs.last().unwrap(),
            );
            let mut h = tape.h.clone();
            self.quantize_h(&mut h); // STE activation quantization
            hs.push(h);
            cs.push(tape.c.clone());
            tapes.push(tape);
            xs.push(x);
        }
        // Head + loss on final hidden state.
        let hw = self.head.effective_w(&self.spec);
        let logits = self.head.forward(&hw, hs.last().unwrap(), 1);
        let (loss, dlogits) = l2svm_loss(&logits, &[label], 1, self.classes);
        self.t += 1;
        let mut ghw = vec![0.0f32; self.head.w.len()];
        let mut ghb = vec![0.0f32; self.head.b.len()];
        let mut dh = self.head.backward(&hw, hs.last().unwrap(), &dlogits, 1, &mut ghw, &mut ghb);
        self.head.adam_step(&ghw, &ghb, self.lr, self.t);
        // BPTT.
        let mut gwx = vec![0.0f32; self.wx.len()];
        let mut gwh = vec![0.0f32; self.wh.len()];
        let mut gb = vec![0.0f32; self.bias.len()];
        let mut dc = vec![0.0f32; self.hidden];
        for r in (0..rows).rev() {
            let (_, dh_prev, dc_prev) = step_dense_backward(
                &wxq, &whq, self.input, self.hidden,
                &xs[r], &hs[r], &cs[r], &tapes[r], &dh, &dc,
                &mut gwx, &mut gwh, &mut gb,
            );
            dh = dh_prev;
            dc = dc_prev;
        }
        crate::model::mlp::adam_update(&mut self.wx, &mut self.mwx, &mut self.vwx, &gwx, self.lr, self.t);
        crate::model::mlp::adam_update(&mut self.wh, &mut self.mwh, &mut self.vwh, &gwh, self.lr, self.t);
        for (b, g) in self.bias.iter_mut().zip(&gb) {
            *b -= self.lr * g;
        }
        for v in self.wx.iter_mut().chain(self.wh.iter_mut()) {
            *v = v.clamp(-1.0, 1.0);
        }
        loss
    }

    pub fn predict(&self, image: &[f32], rows: usize) -> usize {
        let (wxq, whq) = self.effective();
        let mut h = vec![0.0; self.hidden];
        let mut c = vec![0.0; self.hidden];
        for r in 0..rows {
            let mut x = image[r * self.input..(r + 1) * self.input].to_vec();
            if let Some(kin) = self.input_bits {
                let q = crate::quant::quantize(&x, kin, self.spec.method);
                x = q.dequantize();
            }
            let tape = step_dense_tape(&wxq, &whq, &self.bias, self.input, self.hidden, &x, &h, &c);
            h = tape.h;
            self.quantize_h(&mut h);
            c = tape.c;
        }
        let hw = self.head.effective_w(&self.spec);
        let logits = self.head.forward(&hw, &h, 1);
        argmax(&logits)
    }

    pub fn fit(&mut self, train: &ImageSet, test: &ImageSet, epochs: usize, seed: u64) -> f64 {
        let rows = train.height;
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..train.n).collect();
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                self.train_one(train.image(i), rows, train.labels[i]);
            }
        }
        let mut wrong = 0;
        for i in 0..test.n {
            if self.predict(test.image(i), rows) != test.labels[i] {
                wrong += 1;
            }
        }
        wrong as f64 / test.n as f64
    }
}

// ---------------------------------------------------------------------------
// Table 9: VGG-like CNN.
// ---------------------------------------------------------------------------

/// Channel-scaled VGG-like net: (2×C)-MP2-(2×2C)-MP2-(2×4C)-MP2-FC-FC-SVM.
pub struct CnnTrainer {
    pub spec: QuantSpec,
    pub lr: f32,
    convs: Vec<Conv3x3>,
    fc1: DenseLayer,
    fc2: DenseLayer,
    base: usize,
    t: usize,
}

impl CnnTrainer {
    /// `base` = channels of the first block (paper: 128; default scaled).
    pub fn new(base: usize, fc_dim: usize, spec: QuantSpec, lr: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let convs = vec![
            Conv3x3::init(3, base, &mut rng),
            Conv3x3::init(base, base, &mut rng),
            Conv3x3::init(base, 2 * base, &mut rng),
            Conv3x3::init(2 * base, 2 * base, &mut rng),
            Conv3x3::init(2 * base, 4 * base, &mut rng),
            Conv3x3::init(4 * base, 4 * base, &mut rng),
        ];
        let flat = 4 * base * 4 * 4; // 32 → 16 → 8 → 4
        CnnTrainer {
            spec,
            lr,
            convs,
            fc1: DenseLayer::init(fc_dim, flat, &mut rng),
            fc2: DenseLayer::init(10, fc_dim, &mut rng),
            base,
            t: 0,
        }
    }

    fn act(&self, y: &mut Vec<f32>) -> Vec<bool> {
        // As in the MLP: quantized activations replace ReLU (sign codes on
        // the symmetric pre-activation), full precision keeps ReLU.
        match self.spec.k_a {
            Some(ka) => {
                let q = crate::quant::quantize(y, ka, self.spec.method);
                *y = q.dequantize();
                vec![true; y.len()]
            }
            None => relu(y),
        }
    }

    /// Train on one image; returns loss. (Batch = 1 keeps the memory of the
    /// im2col tapes bounded on the 1-core testbed; Adam smooths the noise.)
    pub fn train_one(&mut self, image: &[f32], label: usize) -> f32 {
        let mut shapes = vec![Shape { c: 3, h: 32, w: 32 }];
        let wqs: Vec<Vec<f32>> = self.convs.iter().map(|c| c.effective_w(&self.spec)).collect();
        let mut a = image.to_vec();
        let mut conv_tapes = Vec::new();
        let mut relu_masks = Vec::new();
        let mut pool_args = Vec::new();
        let mut pre_pool_inputs = Vec::new();
        let mut conv_inputs = Vec::new();
        for (ci, conv) in self.convs.iter().enumerate() {
            conv_inputs.push(a.clone());
            let (mut y, tape) = conv.forward(&wqs[ci], &a, *shapes.last().unwrap());
            let mask = self.act(&mut y);
            conv_tapes.push(tape);
            relu_masks.push(mask);
            let s = Shape { c: conv.c_out, ..*shapes.last().unwrap() };
            if ci % 2 == 1 {
                pre_pool_inputs.push(y.clone());
                let (p, arg, os) = maxpool2(&y, s);
                pool_args.push((arg, s.numel()));
                a = p;
                shapes.push(os);
            } else {
                a = y;
                shapes.push(s);
            }
        }
        // FC head.
        let w1 = self.fc1.effective_w(&self.spec);
        let mut h = self.fc1.forward(&w1, &a, 1);
        let mask1 = self.act(&mut h);
        let w2 = self.fc2.effective_w(&self.spec);
        let logits = self.fc2.forward(&w2, &h, 1);
        let (loss, dlogits) = l2svm_loss(&logits, &[label], 1, 10);
        self.t += 1;
        // Backward.
        let mut g2w = vec![0.0f32; self.fc2.w.len()];
        let mut g2b = vec![0.0f32; self.fc2.b.len()];
        let mut dh = self.fc2.backward(&w2, &h, &dlogits, 1, &mut g2w, &mut g2b);
        self.fc2.adam_step(&g2w, &g2b, self.lr, self.t);
        for (d, &m) in dh.iter_mut().zip(&mask1) {
            if !m {
                *d = 0.0;
            }
        }
        let mut g1w = vec![0.0f32; self.fc1.w.len()];
        let mut g1b = vec![0.0f32; self.fc1.b.len()];
        let mut da = self.fc1.backward(&w1, &a, &dh, 1, &mut g1w, &mut g1b);
        self.fc1.adam_step(&g1w, &g1b, self.lr, self.t);
        // Conv blocks in reverse.
        for ci in (0..self.convs.len()).rev() {
            if ci % 2 == 1 {
                let (arg, numel) = pool_args.pop().unwrap();
                da = maxpool2_backward(&da, &arg, numel);
                let _ = pre_pool_inputs.pop();
            }
            for (d, &m) in da.iter_mut().zip(&relu_masks[ci]) {
                if !m {
                    *d = 0.0;
                }
            }
            let conv = &self.convs[ci];
            let mut gw = vec![0.0f32; conv.w.len()];
            let mut gb = vec![0.0f32; conv.b.len()];
            da = conv.backward(&wqs[ci], &conv_tapes[ci], &da, &mut gw, &mut gb);
            self.convs[ci].adam_step(&gw, &gb, self.lr, self.t);
        }
        loss
    }

    pub fn predict(&self, image: &[f32]) -> usize {
        let mut shape = Shape { c: 3, h: 32, w: 32 };
        let mut a = image.to_vec();
        for (ci, conv) in self.convs.iter().enumerate() {
            let wq = conv.effective_w(&self.spec);
            let (mut y, _) = conv.forward(&wq, &a, shape);
            self.act(&mut y);
            shape = Shape { c: conv.c_out, ..shape };
            if ci % 2 == 1 {
                let (p, _, os) = maxpool2(&y, shape);
                a = p;
                shape = os;
            } else {
                a = y;
            }
        }
        let w1 = self.fc1.effective_w(&self.spec);
        let mut h = self.fc1.forward(&w1, &a, 1);
        self.act(&mut h);
        let w2 = self.fc2.effective_w(&self.spec);
        let logits = self.fc2.forward(&w2, &h, 1);
        argmax(&logits)
    }

    pub fn fit(&mut self, train: &ImageSet, test: &ImageSet, epochs: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..train.n).collect();
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                self.train_one(train.image(i), train.labels[i]);
            }
        }
        let mut wrong = 0;
        for i in 0..test.n {
            if self.predict(test.image(i)) != test.labels[i] {
                wrong += 1;
            }
        }
        wrong as f64 / test.n as f64
    }

    pub fn param_count(&self) -> usize {
        self.convs.iter().map(|c| c.w.len()).sum::<usize>() + self.fc1.w.len() + self.fc2.w.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::{cifar_like, mnist_like};

    #[test]
    fn mlp_learns_fp() {
        let train = mnist_like(600, 1);
        let test = mnist_like(200, 2);
        let mut t = MlpTrainer::new(
            MlpConfig {
                layer_sizes: vec![784, 64, 10],
                spec: QuantSpec::full(),
                input_bits: None,
                lr: 1e-3,
                batch: 20,
            },
            3,
        );
        let err = t.fit(&train, &test, 3, 4);
        assert!(err < 0.35, "fp mlp error {err}");
    }

    #[test]
    fn mlp_learns_quantized() {
        // Table 8 setting (scaled): 2-bit in, 2-bit W, 1-bit A.
        let train = mnist_like(600, 5);
        let test = mnist_like(200, 6);
        let mut t = MlpTrainer::new(
            MlpConfig {
                layer_sizes: vec![784, 64, 10],
                spec: QuantSpec::wa(2, 1, Method::Alternating { t: 2 }),
                input_bits: Some(2),
                lr: 1e-3,
                batch: 20,
            },
            7,
        );
        let err = t.fit(&train, &test, 3, 8);
        assert!(err < 0.5, "quantized mlp error {err}");
    }

    #[test]
    fn seq_lstm_learns() {
        let train = mnist_like(300, 9);
        let test = mnist_like(100, 10);
        let mut t = SeqLstmTrainer::new(28, 32, 10, QuantSpec::full(), None, 2e-3, 11);
        let err = t.fit(&train, &test, 2, 12);
        assert!(err < 0.6, "seq lstm error {err}");
    }

    #[test]
    fn cnn_single_steps_reduce_loss() {
        // Full CNN training is exercised by the table9 bench; here we only
        // check the machinery optimizes.
        let train = cifar_like(40, 13);
        let mut t = CnnTrainer::new(4, 32, QuantSpec::full(), 1e-3, 14);
        let mut first = 0.0;
        let mut last = 0.0;
        for pass in 0..6 {
            let mut total = 0.0;
            for i in 0..train.n {
                total += t.train_one(train.image(i), train.labels[i]);
            }
            if pass == 0 {
                first = total;
            }
            last = total;
        }
        assert!(last < first, "loss did not decrease: {first} → {last}");
    }
}
