//! Configuration system: a small TOML-subset parser (sections, strings,
//! ints, floats, bools) plus the typed configs for the launcher.
//!
//! The vendored crate set has no `serde`/`toml`, so the parser is in-tree.
//! Supported grammar — enough for real deployment configs:
//!
//! ```toml
//! [server]
//! addr = "127.0.0.1:7860"
//! max_batch = 16
//! threads = 0          # worker pool: 1 = serial, 0 = auto
//! kernel = "auto"      # GEMM backend: scalar | avx2 | avx512 | neon | auto
//!
//! [model]
//! kind = "lstm"       # or "gru"
//! hidden = 300
//! w_bits = 2
//! a_bits = 2
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key → value` map.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.values
            .get(key)
            .and_then(|v| v.as_int())
            .map(|v| v as usize)
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.values.get(key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.values.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Require a key to exist (for launcher-critical settings).
    pub fn require(&self, key: &str) -> Result<&Value> {
        self.values
            .get(key)
            .with_context(|| format!("config missing required key '{key}'"))
    }

    /// Every `(key, value)` under `[section]`, with the section prefix
    /// stripped, in key order. For open-ended sections whose keys are
    /// user-chosen names, e.g.
    ///
    /// ```toml
    /// [models]
    /// ptb-2bit = "models/ptb-2bit.amqz"
    /// [model_aliases]
    /// prod = "ptb-2bit"
    /// ```
    pub fn section(&self, name: &str) -> Vec<(String, &Value)> {
        let prefix = format!("{name}.");
        self.values
            .iter()
            .filter_map(|(k, v)| k.strip_prefix(&prefix).map(|key| (key.to_string(), v)))
            .collect()
    }
}

/// Parse a human-readable byte size: a plain integer is bytes; `kb`, `mb`,
/// `gb` suffixes (case-insensitive, fractional values allowed) scale by
/// powers of 1024. `0` means "unlimited" to every consumer.
pub fn parse_mem_size(s: &str) -> Result<usize> {
    let s = s.trim().to_ascii_lowercase();
    let (num, scale) = if let Some(n) = s.strip_suffix("gb") {
        (n, 1024.0 * 1024.0 * 1024.0)
    } else if let Some(n) = s.strip_suffix("mb") {
        (n, 1024.0 * 1024.0)
    } else if let Some(n) = s.strip_suffix("kb") {
        (n, 1024.0)
    } else if let Some(n) = s.strip_suffix('b') {
        (n, 1.0)
    } else {
        (s.as_str(), 1.0)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("cannot parse memory size '{s}' (want e.g. 512mb, 2gb)"))?;
    if !v.is_finite() || v < 0.0 {
        bail!("memory size '{s}' out of range");
    }
    Ok((v * scale) as usize)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string {s}");
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{s}'")
}

// ---------------------------------------------------------------------------
// Typed launcher configs.
// ---------------------------------------------------------------------------

use crate::model::{LmConfig, RnnKind};

/// Serving configuration ([server] section).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    pub max_batch: usize,
    /// Batching window: how long the batcher waits to fill a batch.
    pub batch_wait_us: u64,
    pub max_sessions: usize,
    /// Worker-pool size for the batched forward: `1` = serial, `0` = auto
    /// (`AMQ_THREADS` env or the machine's available parallelism).
    pub threads: usize,
    /// XNOR/popcount kernel backend: `"scalar" | "avx2" | "avx512" |
    /// "neon"` forces one, `"auto"` (default) defers to `AMQ_KERNEL` /
    /// runtime feature
    /// detection. Validated by `Kernel::parse_choice` at launch.
    pub kernel: String,
    /// Use the multiplexed event-loop front end (implies continuous
    /// batching). CLI: `--event-loop`.
    pub event_loop: bool,
    /// Event-loop threads; 0 = auto. CLI: `--loops`.
    pub loops: usize,
    /// Continuous-batching slot cap; 0 = use `max_batch`. CLI: `--max-slots`.
    pub max_slots: usize,
    /// Admission-queue bound before `ERR BUSY` load shedding.
    /// CLI: `--queue-depth`.
    pub queue_depth: usize,
    /// Resident-model byte budget for the multi-tenant registry, raw
    /// (`"512mb"`, `"2gb"`, plain bytes; see [`parse_mem_size`]). `None` /
    /// `0` = unlimited. CLI: `--model-mem-budget`.
    pub model_mem_budget: Option<String>,
    /// Per-request wall-clock deadline in milliseconds, checked at timestep
    /// boundaries (`ERR DEADLINE`). 0 = no deadline.
    /// CLI: `--request-deadline-ms`.
    pub request_deadline_ms: u64,
    /// Reap sessions idle longer than this (as if `END` had arrived).
    /// 0 = keep forever (LRU eviction still applies).
    /// CLI: `--session-ttl-secs`.
    pub session_ttl_secs: u64,
    /// Event-loop only: close a connection whose write buffer stays stuck
    /// longer than this. 0 = never. CLI: `--write-stall-ms`.
    pub write_stall_ms: u64,
    /// Where `DRAIN`/SIGTERM snapshots live sessions (`.amqs`). `None`
    /// refuses `DRAIN` with `ERR DRAINING no snapshot path configured`.
    /// CLI: `--snapshot`.
    pub snapshot: Option<String>,
    /// How long a drain lets in-flight decodes finish before cutting the
    /// stragglers with `ERR DRAINING`. CLI: `--drain-deadline-ms`.
    pub drain_deadline_ms: u64,
}

impl ServerConfig {
    pub fn from_config(c: &Config) -> Self {
        ServerConfig {
            addr: c.get_str("server.addr", "127.0.0.1:7860"),
            max_batch: c.get_usize("server.max_batch", 16),
            batch_wait_us: c.get_usize("server.batch_wait_us", 500) as u64,
            max_sessions: c.get_usize("server.max_sessions", 1024),
            threads: c.get_usize("server.threads", 0),
            kernel: c.get_str("server.kernel", "auto"),
            event_loop: c.get_bool("server.event_loop", false),
            loops: c.get_usize("server.loops", 0),
            max_slots: c.get_usize("server.max_slots", 0),
            queue_depth: c.get_usize("server.queue_depth", 128),
            model_mem_budget: c.values.get("server.model_mem_budget").map(|v| match v {
                Value::Str(s) => s.clone(),
                Value::Int(i) => i.to_string(),
                Value::Float(f) => f.to_string(),
                Value::Bool(b) => b.to_string(),
            }),
            request_deadline_ms: c.get_usize("server.request_deadline_ms", 0) as u64,
            session_ttl_secs: c.get_usize("server.session_ttl_secs", 0) as u64,
            write_stall_ms: c.get_usize("server.write_stall_ms", 0) as u64,
            snapshot: c.values.get("server.snapshot").and_then(|v| v.as_str()).map(String::from),
            drain_deadline_ms: c.get_usize("server.drain_deadline_ms", 5000) as u64,
        }
    }
}

/// Model configuration ([model] section).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub lm: LmConfig,
    pub w_bits: usize,
    pub a_bits: usize,
    /// 0 = full precision.
    pub quantized: bool,
    pub checkpoint: Option<String>,
    pub seed: u64,
}

impl ModelConfig {
    pub fn from_config(c: &Config) -> Result<Self> {
        let kind = match c.get_str("model.kind", "lstm").as_str() {
            "lstm" => RnnKind::Lstm,
            "gru" => RnnKind::Gru,
            other => bail!("unknown model.kind '{other}' (lstm|gru)"),
        };
        let w_bits = c.get_usize("model.w_bits", 0);
        let a_bits = c.get_usize("model.a_bits", 0);
        Ok(ModelConfig {
            lm: LmConfig {
                kind,
                vocab: c.get_usize("model.vocab", 10_000),
                hidden: c.get_usize("model.hidden", 300),
                layers: c.get_usize("model.layers", 1),
            },
            w_bits,
            a_bits,
            quantized: w_bits > 0,
            checkpoint: c.values.get("model.checkpoint").and_then(|v| v.as_str()).map(String::from),
            seed: c.get_usize("model.seed", 1) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
[server]
addr = "0.0.0.0:9999"   # bind
max_batch = 32
threads = 4
kernel = "scalar"
event_loop = true
max_slots = 24
queue_depth = 64
request_deadline_ms = 2000
session_ttl_secs = 600
write_stall_ms = 5000
snapshot = "runs/live.amqs"
drain_deadline_ms = 1500
[model]
kind = "gru"
hidden = 512
w_bits = 2
a_bits = 3
dropout = 0.5
quantized = true
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("server.addr", ""), "0.0.0.0:9999");
        assert_eq!(c.get_usize("server.max_batch", 0), 32);
        assert_eq!(c.get_f64("model.dropout", 0.0), 0.5);
        assert!(c.get_bool("model.quantized", false));
    }

    #[test]
    fn typed_configs() {
        let c = Config::parse(SAMPLE).unwrap();
        let s = ServerConfig::from_config(&c);
        assert_eq!(s.max_batch, 32);
        assert_eq!(s.threads, 4);
        assert_eq!(s.kernel, "scalar");
        assert!(s.event_loop);
        assert_eq!((s.max_slots, s.queue_depth), (24, 64));
        assert_eq!(
            (s.request_deadline_ms, s.session_ttl_secs, s.write_stall_ms),
            (2000, 600, 5000)
        );
        assert_eq!(s.snapshot.as_deref(), Some("runs/live.amqs"));
        assert_eq!(s.drain_deadline_ms, 1500);
        let m = ModelConfig::from_config(&c).unwrap();
        assert_eq!(m.lm.kind, RnnKind::Gru);
        assert_eq!(m.lm.hidden, 512);
        assert!(m.quantized);
        assert_eq!((m.w_bits, m.a_bits), (2, 3));
    }

    #[test]
    fn defaults_when_missing() {
        let c = Config::parse("").unwrap();
        let s = ServerConfig::from_config(&c);
        assert_eq!(s.addr, "127.0.0.1:7860");
        assert_eq!(s.kernel, "auto");
        assert!(!s.event_loop);
        assert_eq!((s.loops, s.max_slots, s.queue_depth), (0, 0, 128));
        assert_eq!((s.request_deadline_ms, s.session_ttl_secs, s.write_stall_ms), (0, 0, 0));
        assert!(s.snapshot.is_none(), "drain snapshotting is opt-in");
        assert_eq!(s.drain_deadline_ms, 5000);
    }

    #[test]
    fn errors_are_informative() {
        assert!(Config::parse("[]").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = \"unterminated").is_err());
        let c = Config::parse("[model]\nkind = \"rnn\"").unwrap();
        assert!(ModelConfig::from_config(&c).is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse("x = \"a#b\"").unwrap();
        assert_eq!(c.get_str("x", ""), "a#b");
    }

    #[test]
    fn open_ended_sections_enumerate() {
        let text = r#"
[server]
model_mem_budget = "512mb"
[models]
ptb = "models/ptb.amqz"
wt2 = "models/wt2.amqz"
[model_aliases]
prod = "ptb"
"#;
        let c = Config::parse(text).unwrap();
        let models: Vec<(String, String)> = c
            .section("models")
            .into_iter()
            .map(|(k, v)| (k, v.as_str().unwrap().to_string()))
            .collect();
        assert_eq!(
            models,
            vec![
                ("ptb".to_string(), "models/ptb.amqz".to_string()),
                ("wt2".to_string(), "models/wt2.amqz".to_string()),
            ]
        );
        assert_eq!(c.section("model_aliases").len(), 1);
        assert!(c.section("missing").is_empty());
        let s = ServerConfig::from_config(&c);
        assert_eq!(s.model_mem_budget.as_deref(), Some("512mb"));
    }

    #[test]
    fn mem_sizes_parse() {
        assert_eq!(parse_mem_size("1024").unwrap(), 1024);
        assert_eq!(parse_mem_size("4kb").unwrap(), 4096);
        assert_eq!(parse_mem_size("1.5MB").unwrap(), 1_572_864);
        assert_eq!(parse_mem_size("2gb").unwrap(), 2 * 1024 * 1024 * 1024);
        assert_eq!(parse_mem_size("64b").unwrap(), 64);
        assert_eq!(parse_mem_size("0").unwrap(), 0);
        assert!(parse_mem_size("lots").is_err());
        assert!(parse_mem_size("-1mb").is_err());
    }
}
