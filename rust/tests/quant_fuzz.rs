//! Seeded fuzz harness for every quantizer, hammering the degenerate
//! corners of the input space: all-zero vectors, constant vectors,
//! single-element vectors, extreme (but finite) magnitudes, near-ties and
//! one-hot spikes, at every length 1..=130 (crossing the 64-bit word
//! boundary twice).
//!
//! Invariants checked on every case:
//! * no panic, and every coefficient / reconstruction is finite;
//! * the reconstruction length matches the input;
//! * **alternating is never worse than greedy** — it starts from the greedy
//!   solution and each half-step is non-increasing (Algorithms 1–2), so
//!   this is a theorem, not a statistical claim;
//! * refined is never worse than greedy for k ≤ 2 (where its planes
//!   coincide with greedy's and the coefficients are refit optimally).
//!
//! Deterministic LCG (no deps) so every failure reproduces from the case
//! number printed in the assert message.

use amq::quant::{self, Method, Quantized};

/// Minimal 64-bit LCG (Knuth's MMIX constants) — deterministic, std-only.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform in `[lo, hi)`.
    fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next() >> 40) as f32 / (1u64 << 24) as f32;
        lo + (hi - lo) * u
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// One degenerate input family per index.
fn degenerate_case(family: usize, n: usize, rng: &mut Lcg) -> (&'static str, Vec<f32>) {
    match family {
        0 => ("all-zero", vec![0.0; n]),
        1 => ("constant", vec![0.37; n]),
        2 => ("negative-constant", vec![-1.25e-3; n]),
        3 => {
            // One hot spike in a sea of zeros.
            let mut v = vec![0.0f32; n];
            let i = rng.below(n);
            v[i] = rng.f32(-2.0, 2.0);
            ("one-hot", v)
        }
        4 => {
            // Extreme magnitudes (finite, no ±inf): 1e30 .. 1e-30 mixed.
            ("extreme-magnitudes", (0..n).map(|i| {
                let sign = if i % 2 == 0 { 1.0 } else { -1.0 };
                if i % 3 == 0 {
                    sign * 1e30
                } else if i % 3 == 1 {
                    sign * 1e-30
                } else {
                    sign * 1.0
                }
            }).collect())
        }
        5 => {
            // Exact ± ties — exercises tie-breaking in the BST assignment.
            ("alternating-signs", (0..n).map(|i| if i % 2 == 0 { 0.5 } else { -0.5 }).collect())
        }
        6 => {
            // Tiny subnormal-adjacent values.
            ("tiny", (0..n).map(|_| rng.f32(-1e-38, 1e-38)).collect())
        }
        _ => ("uniform-random", (0..n).map(|_| rng.f32(-3.0, 3.0)).collect()),
    }
}

fn assert_valid(name: &str, method: Method, k: usize, n: usize, w: &[f32], q: &Quantized) {
    assert_eq!(q.n, n, "{name} {method:?} k={k} n={n}: wrong length");
    assert!(
        q.alphas.iter().all(|a| a.is_finite()),
        "{name} {method:?} k={k} n={n}: non-finite alpha {:?}",
        q.alphas
    );
    let hat = q.dequantize();
    assert_eq!(hat.len(), n, "{name} {method:?} k={k} n={n}: wrong reconstruction length");
    assert!(
        hat.iter().all(|v| v.is_finite()),
        "{name} {method:?} k={k} n={n}: non-finite reconstruction"
    );
    assert!(
        q.sq_error(w).is_finite(),
        "{name} {method:?} k={k} n={n}: non-finite error"
    );
}

#[test]
fn quantizers_survive_degenerate_inputs_and_alternating_never_loses_to_greedy() {
    let mut rng = Lcg(0xF00D_F00D);
    let methods = [
        Method::Uniform,
        Method::Balanced,
        Method::Greedy,
        Method::Refined,
        Method::Alternating { t: 2 },
        Method::Alternating { t: 4 },
        Method::Ternary,
    ];
    for n in 1..=130usize {
        for family in 0..8 {
            let (name, w) = degenerate_case(family, n, &mut rng);
            for k in 1..=4usize {
                let greedy_err = quant::quantize(&w, k, Method::Greedy).sq_error(&w);
                for method in methods {
                    let q = quant::quantize(&w, k, method);
                    assert_valid(name, method, k, n, &w, &q);
                    // Alternating starts from greedy and is monotone — it
                    // may never reconstruct worse than greedy.
                    if matches!(method, Method::Alternating { .. }) {
                        let err = q.sq_error(&w);
                        assert!(
                            err <= greedy_err + 1e-5 * (1.0 + greedy_err),
                            "{name} {method:?} k={k} n={n}: alternating {err} > greedy {greedy_err}"
                        );
                    }
                    // Refined ≤ greedy is a theorem for k ≤ 2 (same planes,
                    // optimally refit coefficients).
                    if method == Method::Refined && k <= 2 {
                        let err = q.sq_error(&w);
                        assert!(
                            err <= greedy_err + 1e-5 * (1.0 + greedy_err),
                            "{name} refined k={k} n={n}: {err} > greedy {greedy_err}"
                        );
                    }
                }
            }
        }
    }
}

/// The same degenerate families pushed through the row-quantizer and the
/// batched activation quantizer (threads = 1 and a pool), asserting no
/// panics and serial/parallel bit-equality on hostile inputs.
#[test]
fn matrix_and_batch_quantizers_survive_degenerate_rows() {
    use amq::exec::{Exec, ExecConfig};
    use amq::quant::{QuantizedBatch, RowQuantized};

    let mut rng = Lcg(0xBADC_0FFE);
    let exec = Exec::new(ExecConfig::with_threads(3));
    for rows in [1usize, 2, 5] {
        for cols in [1usize, 63, 64, 65] {
            // Stack a different degenerate family into each row.
            let mut w = Vec::with_capacity(rows * cols);
            for r in 0..rows {
                let (_, row) = degenerate_case(r % 8, cols, &mut rng);
                w.extend(row);
            }
            for method in [Method::Alternating { t: 2 }, Method::Greedy, Method::Ternary] {
                let serial = RowQuantized::quantize(&w, rows, cols, 2, method);
                let par = RowQuantized::quantize_exec(&w, rows, cols, 2, method, &exec);
                assert_eq!(par.alphas, serial.alphas, "{method:?} {rows}x{cols}");
                assert_eq!(par.planes, serial.planes, "{method:?} {rows}x{cols}");
                assert!(serial.dequantize().iter().all(|v| v.is_finite()));
            }
            let serial = QuantizedBatch::quantize(&w, rows, cols, 2);
            let par = QuantizedBatch::quantize_exec(&w, rows, cols, 2, &exec);
            assert_eq!(par.alphas, serial.alphas, "batch {rows}x{cols}");
            assert_eq!(par.data, serial.data, "batch {rows}x{cols}");
        }
    }
}

/// The fuzz grid above is the regression net; this pins the specific
/// corners that historically break quantizers, as named, fast cases.
#[test]
fn named_corner_cases() {
    // n = 1: the k×k least-squares system is rank-1 and the BST has one
    // boundary per level — must not panic or emit NaN for any method.
    for method in [
        Method::Uniform,
        Method::Balanced,
        Method::Greedy,
        Method::Refined,
        Method::Alternating { t: 2 },
        Method::Ternary,
    ] {
        for w in [[0.0f32], [1e30], [-1e-30]] {
            let q = quant::quantize(&w, 3, method);
            assert!(q.dequantize()[0].is_finite(), "{method:?} {w:?}");
        }
    }
    // Constant vector is exactly representable at k = 1 by greedy (α = |c|)
    // and alternating inherits that optimum.
    let w = vec![-0.73f32; 129];
    assert!(quant::quantize(&w, 1, Method::Greedy).sq_error(&w) < 1e-9);
    assert!(quant::quantize(&w, 1, Method::Alternating { t: 2 }).sq_error(&w) < 1e-9);
    // All-zero input reconstructs to exactly zero error for every method.
    let z = vec![0.0f32; 64];
    for method in [Method::Greedy, Method::Alternating { t: 2 }, Method::Uniform] {
        assert!(quant::quantize(&z, 2, method).sq_error(&z) < 1e-12, "{method:?}");
    }
}
