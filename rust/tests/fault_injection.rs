//! Deterministic fault-injection suite over real TCP, against BOTH front
//! ends. An injected lane panic, failed/short/clogged socket writes, and a
//! corrupt `.amqz` reload must each be contained exactly as documented —
//! quarantine + `RELOAD` recovery, closed connection, `ERR` reply — while
//! a concurrent well-formed session keeps producing bit-exact output and
//! STATS' `faults_injected` matches the plan's own count exactly.
//!
//! Plans come from [`FaultPlan::parse`]; when CI exports `AMQ_FAULTS` with
//! a `seed=` entry the tests fold that seed into every plan, so a failure
//! reproduces from the logged command line.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::Duration;

use amq::exec::{Exec, ExecConfig};
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Work};
use amq::server::{tcp, FaultPlan, ModelRegistry};

const VOCAB: usize = 40;

/// Parse a fault plan, folding in CI's `AMQ_FAULTS` seed (if any) so the
/// probabilistic faults replay from the environment's chosen stream.
fn plan(spec: &str) -> Arc<FaultPlan> {
    let mut spec = spec.to_string();
    if let Ok(env) = std::env::var("AMQ_FAULTS") {
        for part in env.split(',') {
            let part = part.trim();
            if part.starts_with("seed=") {
                spec.push(',');
                spec.push_str(part);
            }
        }
    }
    Arc::new(FaultPlan::parse(&spec).expect("valid fault plan"))
}

fn model(seed: u64) -> RnnLm {
    RnnLm::random(
        LmConfig { kind: RnnKind::Lstm, vocab: VOCAB, hidden: 16, layers: 1 },
        seed,
        PrecisionPolicy::quantized(2, 2),
    )
}

/// Publish a tiny model to a temp `.amqz` the registry can load.
fn publish(path: &Path, seed: u64) {
    amq::data::amqz::save(path, &model(seed).to_packed().expect("pack")).expect("save amqz");
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fault_injection_{}_{tag}.amqz", std::process::id()))
}

fn connect(addr: SocketAddr) -> TcpStream {
    let conn = TcpStream::connect(addr).expect("connect");
    // A wedged or panicked server must fail the test quickly, not hang it.
    conn.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    conn
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).expect("server reply");
    line.trim_end().to_string()
}

/// One request on a fresh connection; returns the single reply line.
fn one_shot(addr: SocketAddr, line: &str) -> String {
    let mut conn = connect(addr);
    conn.write_all(line.as_bytes()).expect("send");
    conn.write_all(b"\n").expect("send");
    read_line(&mut BufReader::new(conn))
}

/// Two-model registry over real `.amqz` files (alpha is the default).
fn two_model_registry(alpha: &Path, beta: &Path) -> ModelRegistry {
    let mut registry = ModelRegistry::new(0);
    registry.register_path("alpha", alpha.to_path_buf()).expect("register alpha");
    registry.register_path("beta", beta.to_path_buf()).expect("register beta");
    registry.set_default("alpha").expect("default");
    registry
}

/// The quarantine/reload battery against one live front end. `beta_path`
/// is corrupted and restored mid-suite to exercise a failed reload.
fn quarantine_suite(addr: SocketAddr, fp: &Arc<FaultPlan>, beta_path: &Path, beta_seed: u64) {
    // Ground truth from fresh sessions, before any fault fires. The beta
    // reference comes from a clean (fault-free) in-process server over the
    // same packed file, since beta's own first decode is the panic victim.
    let baseline = one_shot(addr, "GEN 500 6 3,4");
    assert!(baseline.starts_with("OK GEN "), "{baseline}");
    let beta_ref = {
        let mut registry = ModelRegistry::new(0);
        registry.register_path("beta", beta_path.to_path_buf()).expect("register");
        registry.set_default("beta").expect("default");
        let clean = InferenceServer::with_registry(
            registry,
            BatcherConfig { exec: ExecConfig::serial(), ..Default::default() },
            Exec::new(ExecConfig::serial()),
        );
        let (ctx, crx) = mpsc::channel::<Work>();
        let h = std::thread::spawn(move || clean.run(crx));
        let r = tcp::handle_line("GEN 602 6 1,2 MODEL beta", &ctx);
        ctx.send(Work::Shutdown).expect("clean shutdown");
        h.join().expect("clean join");
        r
    };
    assert!(beta_ref.starts_with("OK GEN "), "{beta_ref}");

    // A well-formed alpha client decodes concurrently with the panic; its
    // fresh session must produce exactly the baseline tokens.
    let concurrent = std::thread::spawn(move || one_shot(addr, "GEN 501 6 3,4"));

    // The victim: beta's lane panics at its 4th decode timestep, killing
    // only the in-flight beta session.
    let victim = one_shot(addr, "GEN 600 10 1,2 MODEL beta");
    assert_eq!(victim, "ERR INTERNAL lane beta poisoned");

    // Subsequent beta requests are refused while quarantined.
    let refused = one_shot(addr, "GEN 601 3 1 MODEL beta");
    assert_eq!(
        refused,
        "ERR MODEL_POISONED model 'beta' quarantined after a lane panic; \
         RELOAD beta to restore"
    );

    // RELOAD against a corrupt file fails loudly and KEEPS the quarantine.
    std::fs::write(beta_path, b"definitely not an amqz file").expect("corrupt");
    let failed = one_shot(addr, "RELOAD beta");
    assert!(failed.starts_with("ERR model beta:"), "{failed}");
    let still = one_shot(addr, "GEN 601 3 1 MODEL beta");
    assert!(still.starts_with("ERR MODEL_POISONED "), "{still}");

    // Restore the artifact; RELOAD now clears the poison and beta decodes
    // bit-exactly against the clean reference.
    publish(beta_path, beta_seed);
    assert_eq!(one_shot(addr, "RELOAD beta"), "OK RELOAD beta");
    assert_eq!(one_shot(addr, "GEN 602 6 1,2 MODEL beta"), beta_ref);

    // Alpha never noticed: the concurrent session and a fresh one both
    // bit-match the pre-fault baseline.
    assert_eq!(concurrent.join().expect("join"), baseline, "panic must not perturb alpha");
    assert_eq!(one_shot(addr, "GEN 503 6 3,4"), baseline);

    // Exact injected-vs-observed crosscheck: one panic planned, one fired,
    // one counted.
    let stats = one_shot(addr, "STATS");
    assert!(stats.contains("\"lane_panics\":1"), "{stats}");
    assert_eq!(fp.injected(), 1, "exactly the planned panic fired");
    assert!(stats.contains(&format!("\"faults_injected\":{}", fp.injected())), "{stats}");
}

#[test]
fn lane_panic_quarantine_and_reload_thread_per_conn() {
    let (alpha, beta) = (tmp("tpc_alpha"), tmp("tpc_beta"));
    publish(&alpha, 3);
    publish(&beta, 4);
    let fp = plan("panic_lane=beta@4");
    let server = InferenceServer::with_registry(
        two_model_registry(&alpha, &beta),
        BatcherConfig {
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
        Exec::new(ExecConfig::serial()),
    );
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    let (addr_tx, addr_rx) = mpsc::channel();
    let tx2: Sender<Work> = tx.clone();
    let srv = std::thread::spawn(move || {
        tcp::serve("127.0.0.1:0", tx2, flag, move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv().expect("bound");

    quarantine_suite(addr, &fp, &beta, 4);

    // Shutdown joins every thread even after a quarantine.
    shutdown.store(true, Ordering::SeqCst);
    srv.join().expect("front end").expect("serve ok");
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
    let _ = std::fs::remove_file(&alpha);
    let _ = std::fs::remove_file(&beta);
}

#[cfg(unix)]
#[test]
fn lane_panic_quarantine_and_reload_event_loop() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let (alpha, beta) = (tmp("el_alpha"), tmp("el_beta"));
    publish(&alpha, 3);
    publish(&beta, 4);
    let fp = plan("panic_lane=beta@4");
    let server = InferenceServer::with_registry(
        two_model_registry(&alpha, &beta),
        BatcherConfig {
            continuous: true,
            max_slots: 8,
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
        Exec::new(ExecConfig::serial()),
    );
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 2, faults: Some(fp.clone()), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    quarantine_suite(srv.addr, &fp, &beta, 4);

    srv.shutdown();
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
    let _ = std::fs::remove_file(&alpha);
    let _ = std::fs::remove_file(&beta);
}

/// Socket-level faults (short reads, short writes) must be invisible in
/// content: every reply of a pipelined battery equals the clean server's,
/// byte for byte — only the fragmentation differs.
#[cfg(unix)]
#[test]
fn short_reads_and_writes_stay_bit_exact() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let battery = [
        "GEN 1 5 2,3",
        "SCORE 1,2,3,4",
        "GEN 1 4 7",
        "END 1",
        "GEN 2 6 5",
        "END 2",
        "END 99",
    ];
    // Clean reference replies, no sockets involved.
    let expected: Vec<String> = {
        let clean = InferenceServer::new(
            Arc::new(model(5)),
            BatcherConfig { continuous: true, exec: ExecConfig::serial(), ..Default::default() },
        );
        let (ctx, crx) = mpsc::channel::<Work>();
        let h = std::thread::spawn(move || clean.run(crx));
        let replies = battery.iter().map(|line| tcp::handle_line(line, &ctx)).collect();
        ctx.send(Work::Shutdown).expect("clean shutdown");
        h.join().expect("clean join");
        replies
    };

    let fp = plan("short_write=0.5,short_read=0.25");
    let server = InferenceServer::new(
        Arc::new(model(5)),
        BatcherConfig {
            continuous: true,
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 1, faults: Some(fp.clone()), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    // One pipelined burst so reads fragment mid-line too.
    let mut conn = connect(srv.addr);
    let mut payload = String::new();
    for line in &battery {
        payload.push_str(line);
        payload.push('\n');
    }
    conn.write_all(payload.as_bytes()).expect("send");
    let mut r = BufReader::new(conn);
    for want in &expected {
        assert_eq!(&read_line(&mut r), want, "fragmented I/O must not change content");
    }

    srv.shutdown();
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
}

/// An injected write failure kills exactly the one connection; the server
/// keeps accepting and serving.
#[cfg(unix)]
#[test]
fn failed_write_closes_one_connection_only() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let fp = plan("write_err=1");
    let server = InferenceServer::new(
        Arc::new(model(5)),
        BatcherConfig {
            continuous: true,
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 1, faults: Some(fp.clone()), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    // Sacrificial connection: its first reply write errors, so the server
    // closes it — the client sees EOF (or a reset), never a partial line.
    let mut sac = connect(srv.addr);
    sac.write_all(b"STATS\n").expect("send");
    let mut buf = Vec::new();
    match sac.read_to_end(&mut buf) {
        Ok(n) => assert_eq!(n, 0, "failed write must close the connection, got {buf:?}"),
        Err(_) => {} // ECONNRESET is an equally valid observation
    }
    assert_eq!(fp.injected(), 1);

    // The next connection is served normally.
    let ok = one_shot(srv.addr, "GEN 5 3 1");
    assert!(ok.starts_with("OK GEN "), "{ok}");

    srv.shutdown();
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
}

/// Injected accept failures delay accepts (level-triggered retry) but
/// never refuse a client.
#[cfg(unix)]
#[test]
fn accept_errors_delay_but_never_refuse() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let fp = plan("accept_err=3");
    let server = InferenceServer::new(
        Arc::new(model(5)),
        BatcherConfig {
            continuous: true,
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 1, faults: Some(fp.clone()), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    let ok = one_shot(srv.addr, "GEN 5 3 1");
    assert!(ok.starts_with("OK GEN "), "{ok}");
    assert_eq!(fp.injected(), 3, "all three accept faults fired before the accept succeeded");

    srv.shutdown();
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
}

/// A request that overstays `request_deadline` answers `ERR DEADLINE` on
/// the wire at a timestep boundary (an injected lane stall makes it
/// overstay deterministically).
#[cfg(unix)]
#[test]
fn deadline_expires_over_the_wire() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let fp = plan("stall_lane=default@7:2500");
    let server = InferenceServer::new(
        Arc::new(model(5)),
        BatcherConfig {
            continuous: true,
            max_slots: 8,
            // Generous deadline: CI jitter before the first timestep must
            // not expire anything — only the injected 2.5 s stall can.
            request_deadline: Some(Duration::from_millis(1000)),
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 1, faults: Some(fp.clone()), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    let victim = one_shot(srv.addr, "GEN 1 3000 3,4");
    assert_eq!(victim, "ERR DEADLINE request exceeded 1000ms deadline");
    let stats = one_shot(srv.addr, "STATS");
    assert!(stats.contains("\"deadline_expirations\":1"), "{stats}");
    assert!(stats.contains(&format!("\"faults_injected\":{}", fp.injected())), "{stats}");

    // The lane recovers: the next request decodes normally.
    let ok = one_shot(srv.addr, "GEN 2 3 1");
    assert!(ok.starts_with("OK GEN "), "{ok}");

    srv.shutdown();
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
}

/// A clogged connection (peer never drains) is closed by the write-stall
/// sweep and counted; everyone else keeps being served.
#[cfg(unix)]
#[test]
fn write_stall_closes_clogged_connection() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let fp = plan("clog_write=1");
    let server = InferenceServer::new(
        Arc::new(model(5)),
        BatcherConfig {
            continuous: true,
            faults: Some(fp.clone()),
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let counters = server.counters.clone();
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig {
        loops: 1,
        write_stall: Some(Duration::from_millis(150)),
        counters: Some(counters),
        faults: Some(fp.clone()),
        ..Default::default()
    };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    // Victim: its first reply clogs in the injected always-blocked socket;
    // the sweep closes the connection once the 150 ms bound passes.
    let mut sac = connect(srv.addr);
    sac.write_all(b"GEN 7 3 1\n").expect("send");
    let mut buf = Vec::new();
    match sac.read_to_end(&mut buf) {
        Ok(n) => assert_eq!(n, 0, "stalled connection must be closed, got {buf:?}"),
        Err(_) => {}
    }
    assert_eq!(fp.injected(), 1);

    // Other clients are untouched, and the close was counted.
    let ok = one_shot(srv.addr, "GEN 8 3 1");
    assert!(ok.starts_with("OK GEN "), "{ok}");
    let stats = one_shot(srv.addr, "STATS");
    assert!(stats.contains("\"write_stall_closes\":1"), "{stats}");

    srv.shutdown();
    tx.send(Work::Shutdown).expect("batcher alive");
    batcher.join().expect("batcher joins");
}
