//! Golden cross-layer test: the Layer-1 Pallas quantization kernel (executed
//! through its AOT artifact) and the native Rust implementation of
//! Algorithm 2 must agree on the same input matrix.
//!
//! Reconstruction values can differ on exact argmin ties and the kernel's
//! ridge term, so the contract is: per-row reconstruction error within a
//! tight relative band, and global relative MSE essentially identical.

use std::path::Path;

use amq::quant::{alternating, relative_mse};
use amq::runtime::{Arg, Engine, HostTensor};
use amq::util::Rng;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("quant_k2.hlo.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn run_case(k: usize) {
    let Some(dir) = artifacts() else { return };
    let (rows, cols) = (64usize, 128usize);
    let mut rng = Rng::new(0xC0FFEE + k as u64);
    let w = rng.laplace_vec(rows * cols, 0.1);

    let mut engine = Engine::cpu(dir).unwrap();
    engine.load(&format!("quant_k{k}")).unwrap();
    let wt = HostTensor::new(vec![rows, cols], w.clone());
    let out = engine.execute(&format!("quant_k{k}"), &[Arg::F32(&wt)]).unwrap();
    assert_eq!(out.len(), 1);
    let kernel_hat = &out[0].data;
    assert_eq!(kernel_hat.len(), rows * cols);

    // Native per-row quantization.
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        let native = alternating::quantize(row, k, 2);
        let e_native = native.sq_error(row);
        let e_kernel: f64 = row
            .iter()
            .zip(&kernel_hat[r * cols..(r + 1) * cols])
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let denom = e_native.max(1e-12);
        assert!(
            (e_kernel - e_native).abs() / denom < 0.05,
            "row {r}: kernel err {e_kernel:.6} vs native {e_native:.6}"
        );
    }

    // Global relative MSE must land in the same band.
    let g_kernel = relative_mse(&w, kernel_hat);
    let native_all: Vec<f32> = (0..rows)
        .flat_map(|r| alternating::quantize(&w[r * cols..(r + 1) * cols], k, 2).dequantize())
        .collect();
    let g_native = relative_mse(&w, &native_all);
    assert!(
        (g_kernel - g_native).abs() / g_native < 0.02,
        "global rMSE: kernel {g_kernel:.5} vs native {g_native:.5}"
    );
}

#[test]
fn pallas_kernel_matches_native_k2() {
    run_case(2);
}

#[test]
fn pallas_kernel_matches_native_k3() {
    run_case(3);
}
