//! A counting `#[global_allocator]` with **thread-local** counters —
//! shared (via `#[path]` include) by `rust/tests/workspace_parity.rs` and
//! `rust/benches/server_throughput.rs`, so the test gate and the bench
//! gate measure allocations with the same bookkeeping.
//!
//! Including this module installs the allocator for the whole binary.
//! Per-thread counting means worker-pool threads and concurrently running
//! harness tests never pollute a serial measurement window: snapshot
//! [`thread_alloc_counts`] before and after the measured region on the
//! measuring thread.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static THREAD_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Counts heap allocations made by the current thread.
struct CountingAlloc;

fn note_alloc(bytes: usize) {
    // try_with: the allocator may run during TLS teardown; drop the count
    // rather than panic. Const-initialized Cells never allocate or recurse.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    let _ = THREAD_BYTES.try_with(|c| c.set(c.get() + bytes as u64));
}

// SAFETY: delegates every operation to System; the bookkeeping is two
// const-initialized thread-local Cells, which cannot allocate or recurse.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note_alloc(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note_alloc(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        note_alloc(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// `(allocations, bytes)` requested so far by the **current thread**.
pub fn thread_alloc_counts() -> (u64, u64) {
    (THREAD_ALLOCS.with(|c| c.get()), THREAD_BYTES.with(|c| c.get()))
}
