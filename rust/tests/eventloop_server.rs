//! End-to-end tests for the event-loop front end + continuous batcher:
//! bit-exactness of continuous batching against a sequential reference
//! over real TCP, in-order pipelined replies, `ERR BUSY` load shedding,
//! machine-readable `STATS`, and clean shutdown.

#![cfg(unix)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use amq::exec::ExecConfig;
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Request, Respond, Work};
use amq::server::eventloop::{self, EventLoopConfig, EventLoopServer};
use amq::server::protocol::format_reply;

/// The same model for every server in a test: `random` is seed-determined,
/// so two instances are bit-identical.
fn model() -> Arc<RnnLm> {
    Arc::new(RnnLm::random(
        LmConfig { kind: RnnKind::Lstm, vocab: 60, hidden: 24, layers: 1 },
        123,
        PrecisionPolicy::quantized(2, 2),
    ))
}

fn start_continuous(
    max_slots: usize,
    queue_depth: usize,
    threads: usize,
) -> (EventLoopServer, Sender<Work>, std::thread::JoinHandle<()>) {
    let server = InferenceServer::new(
        model(),
        BatcherConfig {
            max_batch: max_slots,
            continuous: true,
            max_slots,
            queue_depth,
            exec: ExecConfig::with_threads(threads),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 2, ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");
    (srv, tx, batcher)
}

fn stop(srv: EventLoopServer, work: Sender<Work>, batcher: std::thread::JoinHandle<()>) {
    srv.shutdown();
    work.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
}

fn send_line(conn: &mut TcpStream, line: &str) {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

/// Run `GEN <session> <max_new> <prime,…>` lines one at a time against a
/// fresh `max_batch = 1` grouped server on the serial engine — the
/// sequential ground truth every concurrent schedule must bit-match.
fn sequential_gen_reference(lines: &[impl AsRef<str>]) -> Vec<String> {
    let server = InferenceServer::new(
        model(),
        BatcherConfig {
            max_batch: 1,
            continuous: false,
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(rx));
    let out = lines
        .iter()
        .map(|line| {
            let rest = line.as_ref().strip_prefix("GEN ").expect("reference lines are GEN");
            let mut parts = rest.split_whitespace();
            let session: u64 = parts.next().unwrap().parse().unwrap();
            let max_new: usize = parts.next().unwrap().parse().unwrap();
            let prime: Vec<usize> =
                parts.next().unwrap().split(',').map(|t| t.parse().unwrap()).collect();
            let (rtx, rrx) = mpsc::channel();
            tx.send(Work::Gen(Request {
                session,
                max_new,
                prime,
                model: None,
                respond: Respond::Channel(rtx),
                enqueued: Instant::now(),
            }))
            .unwrap();
            format_reply(&rrx.recv().unwrap())
        })
        .collect();
    tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
    out
}

/// Continuous batching over the event loop must produce exactly the bytes
/// a `max_batch = 1` sequential grouped server produces — concurrent
/// staggered clients, mid-decode joins and finishes, multi-threaded exec,
/// zero tolerance.
#[test]
fn continuous_eventloop_bitmatches_sequential_reference() {
    const CLIENTS: usize = 6;
    // Two generations per session (the second continues stored state),
    // lengths varied so finishes interleave with joins mid-decode.
    let script = |i: usize| {
        let (p1, p2, p3) = (i % 60, (i * 7 + 3) % 60, (i * 11 + 5) % 60);
        (
            format!("GEN {i} {} {p1},{p2}", 32 + 4 * i),
            format!("GEN {i} {} {p3}", 16 + 2 * i),
        )
    };

    // Sequential reference: grouped batcher, one request at a time, serial
    // exec, driven directly over the Work channel.
    let lines: Vec<String> = (0..CLIENTS)
        .flat_map(|i| {
            let (g1, g2) = script(i);
            [g1, g2]
        })
        .collect();
    let flat = sequential_gen_reference(&lines);
    let reference: Vec<(String, String)> =
        flat.chunks(2).map(|c| (c[0].clone(), c[1].clone())).collect();
    assert!(reference.iter().all(|(a, b)| a.starts_with("OK GEN ") && b.starts_with("OK GEN ")));

    // Continuous server: few slots so clients queue and join mid-decode.
    let (srv, work, batcher) = start_continuous(2, 64, 2);
    let addr = srv.addr;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_micros(500) * i as u32);
                let (g1, g2) = script(i);
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut r = BufReader::new(conn.try_clone().unwrap());
                send_line(&mut conn, &g1);
                let a = read_line(&mut r);
                send_line(&mut conn, &g2);
                let b = read_line(&mut r);
                send_line(&mut conn, &format!("END {i}"));
                assert_eq!(read_line(&mut r), "OK END");
                (a, b)
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        assert_eq!(
            got, reference[i],
            "session {i}: continuous batching diverged from the sequential reference"
        );
    }

    // The run must actually have used the continuous decode path.
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "STATS");
    let stats = read_line(&mut r);
    assert!(stats.contains("\"mode\":\"continuous\""), "{stats}");
    assert!(!stats.contains("\"decode_timesteps\":0,"), "{stats}");
    stop(srv, work, batcher);
}

/// Pipelined commands on one connection answer strictly in request order
/// (a quick STATS completes long before the GEN ahead of it), and two
/// pipelined GENs on the *same session* serialize: the second bit-matches
/// the sequential continuation, despite free slots it could have grabbed.
#[test]
fn pipelined_commands_answer_in_order() {
    let reference = sequential_gen_reference(&["GEN 900 24 1,2", "GEN 900 4 5"]);
    let (srv, work, batcher) = start_continuous(4, 64, 1);
    let mut conn = TcpStream::connect(srv.addr).unwrap();
    let mut r = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"GEN 900 24 1,2\nSTATS\nFROB\nSCORE 1,2,3,4\nGEN 900 4 5\nEND 900\n").unwrap();
    let a = read_line(&mut r);
    assert_eq!(a, reference[0]);
    assert!(read_line(&mut r).starts_with("OK STATS {"));
    assert!(read_line(&mut r).starts_with("ERR "));
    assert!(read_line(&mut r).starts_with("OK SCORE "));
    let b = read_line(&mut r);
    assert_eq!(b, reference[1], "pipelined same-session GEN must continue, not restart");
    assert_eq!(read_line(&mut r), "OK END");
    stop(srv, work, batcher);
}

/// Admission control over TCP: a simultaneous burst against one slot and a
/// depth-1 queue sheds with `ERR BUSY`; every client still gets an answer,
/// and `STATS` reports the shed count.
#[test]
fn busy_shedding_under_burst() {
    const CLIENTS: usize = 12;
    let (srv, work, batcher) = start_continuous(1, 1, 1);
    let addr = srv.addr;
    let barrier = Arc::new(std::sync::Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut r = BufReader::new(conn.try_clone().unwrap());
                barrier.wait();
                send_line(&mut conn, &format!("GEN {i} 512 {}", (i * 13 + 1) % 60));
                read_line(&mut r)
            })
        })
        .collect();
    let (mut served, mut shed) = (0, 0);
    for h in handles {
        let reply = h.join().unwrap();
        if reply.starts_with("OK GEN ") {
            assert_eq!(reply.trim_start_matches("OK GEN ").split(',').count(), 512);
            served += 1;
        } else {
            assert!(reply.starts_with("ERR BUSY "), "{reply}");
            shed += 1;
        }
    }
    assert_eq!(served + shed, CLIENTS, "every client must get an answer");
    assert!(served > 0, "at least the slot+queue occupants are served");
    assert!(shed > 0, "a 12-deep burst against slot=1/depth=1 must shed");

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "STATS");
    let stats = read_line(&mut r);
    let shed_reported: usize = stats
        .split("\"shed\":")
        .nth(1)
        .and_then(|s| s.split(',').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no shed field in {stats}"));
    assert_eq!(shed_reported, shed, "{stats}");
    stop(srv, work, batcher);
}

/// STATS carries the machine-readable serving state on one line.
#[test]
fn stats_json_is_single_line_and_complete() {
    let (srv, work, batcher) = start_continuous(4, 64, 1);
    let mut conn = TcpStream::connect(srv.addr).unwrap();
    let mut r = BufReader::new(conn.try_clone().unwrap());
    send_line(&mut conn, "GEN 5 8 1,2");
    assert!(read_line(&mut r).starts_with("OK GEN "));
    send_line(&mut conn, "STATS");
    let stats = read_line(&mut r);
    let payload = stats.strip_prefix("OK STATS ").unwrap();
    assert!(payload.starts_with('{') && payload.ends_with('}'), "{payload}");
    for key in [
        "\"mode\":\"continuous\"",
        "\"active_slots\":",
        "\"max_slots\":4",
        "\"queued\":",
        "\"queue_depth\":64",
        "\"shed\":0",
        "\"requests\":1",
        "\"tokens_generated\":8",
        "\"decode_timesteps\":",
        "\"kernel\":\"",
        "\"threads\":1",
        "\"latency_us\":{\"count\":1,",
    ] {
        assert!(payload.contains(key), "missing {key} in {payload}");
    }
    // Human form on request.
    send_line(&mut conn, "STATS TEXT");
    let text = read_line(&mut r);
    assert!(text.starts_with("OK STATS latency:"), "{text}");
    assert!(text.contains("mode=continuous"), "{text}");
    stop(srv, work, batcher);
}

/// Shutdown with live connections and in-flight-free batcher joins every
/// loop thread; a subsequent bind to the same port family still works.
#[test]
fn shutdown_joins_loop_threads() {
    let (srv, work, batcher) = start_continuous(2, 8, 1);
    let _idle = TcpStream::connect(srv.addr).unwrap();
    let mut busy = TcpStream::connect(srv.addr).unwrap();
    let mut r = BufReader::new(busy.try_clone().unwrap());
    send_line(&mut busy, "GEN 3 4 7");
    assert!(read_line(&mut r).starts_with("OK GEN "));
    stop(srv, work, batcher); // joins loops + batcher; must not hang
}
