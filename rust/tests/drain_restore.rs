//! Zero-downtime ops, end to end over real TCP on BOTH front ends:
//!
//! * **drain → snapshot → restart → restore is byte-identical**: a session
//!   generated on instance A, drained to a checksummed `.amqs` snapshot,
//!   and revived on a fresh instance B must produce exactly the tokens the
//!   same session would have produced on one uninterrupted server — zero
//!   tolerance, compared reply-line for reply-line.
//! * **mid-decode drains cut stragglers**: a generation still in a slot
//!   when the drain deadline lapses answers `ERR DRAINING` and its session
//!   is dropped (the client cannot know how far it got), while the drain
//!   itself still completes and snapshots what remains.
//! * **a torn publish is refused at load**: `save_with_faults` with
//!   `torn_write=N` mangles a published `.amqz`; serving it must answer
//!   `ERR MODEL_CORRUPT <name> <section>: …` — and the STATS counters
//!   (`faults_injected`, `corrupt_loads_rejected`) must cross-check against
//!   the plan's own fire count exactly.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use amq::data::amqz;
use amq::exec::{Exec, ExecConfig};
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Work};
use amq::server::{tcp, FaultPlan, ModelRegistry};

const VOCAB: usize = 40;

fn model() -> Arc<RnnLm> {
    Arc::new(RnnLm::random(
        LmConfig { kind: RnnKind::Lstm, vocab: VOCAB, hidden: 16, layers: 1 },
        5,
        PrecisionPolicy::quantized(2, 2),
    ))
}

fn temp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("drain_restore_{}_{tag}", std::process::id()))
}

fn connect(addr: SocketAddr) -> TcpStream {
    let conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    conn
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).expect("server reply");
    line.trim_end().to_string()
}

/// One request on a fresh connection; returns the single reply line.
fn one_shot(addr: SocketAddr, line: &str) -> String {
    let mut conn = connect(addr);
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    read_line(&mut BufReader::new(conn))
}

/// A live front end serving one batcher; `stop` tears the whole stack down.
struct Running {
    addr: SocketAddr,
    stop: Box<dyn FnOnce()>,
}

fn spawn_tcp(server: InferenceServer) -> Running {
    let health = server.health.clone();
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    let (addr_tx, addr_rx) = mpsc::channel();
    let tx2 = tx.clone();
    let srv = std::thread::spawn(move || {
        tcp::serve_with_health("127.0.0.1:0", tx2, flag, Some(health), move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv().unwrap();
    Running {
        addr,
        stop: Box::new(move || {
            shutdown.store(true, Ordering::SeqCst);
            srv.join().unwrap().unwrap();
            tx.send(Work::Shutdown).unwrap();
            batcher.join().unwrap();
        }),
    }
}

#[cfg(unix)]
fn spawn_eventloop(server: InferenceServer) -> Running {
    use amq::server::eventloop::{self, EventLoopConfig};
    let health = server.health.clone();
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 2, health: Some(health), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");
    let addr = srv.addr;
    Running {
        addr,
        stop: Box::new(move || {
            srv.shutdown();
            tx.send(Work::Shutdown).unwrap();
            batcher.join().unwrap();
        }),
    }
}

fn cfg(continuous: bool, snapshot: Option<PathBuf>) -> BatcherConfig {
    BatcherConfig {
        max_batch: 4,
        continuous,
        max_slots: 4,
        queue_depth: 16,
        exec: ExecConfig::serial(),
        snapshot_path: snapshot,
        drain_deadline: Duration::from_millis(2000),
        ..Default::default()
    }
}

/// The full rolling-restart cycle against one front end. The reference is
/// an uninterrupted server answering the same two sequential requests on
/// one session — the drained-and-restored pair must match it reply-line
/// for reply-line.
fn drain_restore_cycle(tag: &str, continuous: bool, spawn: &dyn Fn(InferenceServer) -> Running) {
    let snap = temp(&format!("snap_{tag}.amqs"));
    let m = model();

    let reference = spawn(InferenceServer::new(m.clone(), cfg(continuous, None)));
    let first_ref = one_shot(reference.addr, "GEN 9 3 4");
    let second_ref = one_shot(reference.addr, "GEN 9 3 11");
    assert!(first_ref.starts_with("OK GEN "), "{first_ref}");
    assert!(second_ref.starts_with("OK GEN "), "{second_ref}");
    (reference.stop)();

    // Instance A: serve the first request, then drain.
    let a = spawn(InferenceServer::new(m.clone(), cfg(continuous, Some(snap.clone()))));
    assert_eq!(one_shot(a.addr, "GEN 9 3 4"), first_ref, "{tag}: pre-drain decode diverged");
    let drained = one_shot(a.addr, "DRAIN");
    assert!(drained.starts_with("OK DRAIN 1 "), "{tag}: one saved session: {drained}");
    assert_eq!(
        one_shot(a.addr, "GEN 10 3 4"),
        "ERR DRAINING server is draining; retry against another instance",
        "{tag}: admission must stop after a drain"
    );
    let health = one_shot(a.addr, "HEALTH");
    assert!(health.starts_with("OK HEALTH draining"), "{tag}: {health}");
    let stats = one_shot(a.addr, "STATS");
    assert!(stats.contains("\"drains\":1"), "{tag}: {stats}");
    assert!(stats.contains("\"sessions_snapshotted\":1"), "{tag}: {stats}");
    assert!(stats.contains("\"health\":\"draining\""), "{tag}: {stats}");
    (a.stop)();

    // Instance B: fresh process stand-in — restore before serving, then
    // the session's next request must continue bit-exactly.
    let mut fresh = InferenceServer::new(m.clone(), cfg(continuous, Some(snap.clone())));
    assert_eq!(fresh.restore_sessions(&snap).unwrap(), 1, "{tag}: one session to revive");
    let b = spawn(fresh);
    assert_eq!(
        one_shot(b.addr, "GEN 9 3 11"),
        second_ref,
        "{tag}: restored continuation must be byte-identical to the uninterrupted run"
    );
    let stats = one_shot(b.addr, "STATS");
    assert!(stats.contains("\"sessions_restored\":1"), "{tag}: {stats}");
    assert!(stats.contains("\"health\":\"ok\""), "{tag}: a restored server is healthy: {stats}");
    (b.stop)();
    std::fs::remove_file(&snap).ok();
}

#[test]
fn drain_restore_is_byte_identical_thread_per_conn() {
    drain_restore_cycle("tcp", false, &spawn_tcp);
}

#[cfg(unix)]
#[test]
fn drain_restore_is_byte_identical_event_loop() {
    drain_restore_cycle("eventloop", true, &spawn_eventloop);
}

#[cfg(unix)]
#[test]
fn mid_decode_drain_cuts_stragglers_over_tcp() {
    let snap = temp("snap_cut.amqs");
    let mut config = cfg(true, Some(snap.clone()));
    config.drain_deadline = Duration::from_millis(0);
    let srv = spawn_eventloop(InferenceServer::new(model(), config));

    // One pipelined write: a generation too long to finish inside a zero
    // drain deadline, then the drain. In-order replies: the straggler is
    // cut first, then the drain reports zero saved sessions (the cut
    // session dropped — the client cannot know how far it got).
    let mut conn = connect(srv.addr);
    conn.write_all(b"GEN 77 4096 1\nDRAIN\n").unwrap();
    let mut r = BufReader::new(conn);
    assert_eq!(
        read_line(&mut r),
        "ERR DRAINING server is draining; retry against another instance"
    );
    let drained = read_line(&mut r);
    assert!(drained.starts_with("OK DRAIN 0 "), "cut sessions are not snapshotted: {drained}");
    drop(r);

    let stats = one_shot(srv.addr, "STATS");
    assert!(stats.contains("\"drains\":1"), "{stats}");
    assert!(stats.contains("\"sessions_snapshotted\":0"), "{stats}");
    (srv.stop)();
    std::fs::remove_file(&snap).ok();
}

#[test]
fn torn_publish_is_refused_at_load_with_model_corrupt() {
    let m = model();
    let good_path = temp("good.amqz");
    let torn_path = temp("torn.amqz");
    amqz::save(&good_path, &m.to_packed().unwrap()).unwrap();

    // One plan is both the publish mangler and the serving batcher's
    // plan, so STATS `faults_injected` counts exactly the torn write and
    // the test can cross-check injected vs rejected with no slack.
    let plan = Arc::new(FaultPlan::parse("torn_write=96").unwrap());
    amqz::save_with_faults(&torn_path, &m.to_packed().unwrap(), Some(plan.as_ref())).unwrap();
    assert_eq!(plan.injected(), 1, "the torn write must have fired");

    let mut registry = ModelRegistry::new(0);
    registry.register_path("good", good_path.clone()).unwrap();
    registry.register_path("torn", torn_path.clone()).unwrap();
    registry.set_default("good").unwrap();
    let server = InferenceServer::with_registry(
        registry,
        BatcherConfig {
            max_batch: 2,
            exec: ExecConfig::serial(),
            faults: Some(plan.clone()),
            ..Default::default()
        },
        Exec::serial(),
    );
    let srv = spawn_tcp(server);

    let ok = one_shot(srv.addr, "GEN 1 3 2 MODEL good");
    assert!(ok.starts_with("OK GEN "), "the intact publish serves: {ok}");
    // Both the lazy first-use load and the eager RELOAD must refuse the
    // mangled file with the wire taxonomy naming the damaged section.
    let err = one_shot(srv.addr, "GEN 2 3 2 MODEL torn");
    assert!(err.starts_with("ERR MODEL_CORRUPT torn "), "{err}");
    let err = one_shot(srv.addr, "RELOAD torn");
    assert!(err.starts_with("ERR MODEL_CORRUPT torn "), "{err}");

    let stats = one_shot(srv.addr, "STATS");
    assert!(stats.contains("\"corrupt_loads_rejected\":2"), "{stats}");
    assert!(
        stats.contains(&format!("\"faults_injected\":{}", plan.injected())),
        "STATS must report exactly the plan's fire count: {stats}"
    );
    assert_eq!(plan.injected(), 1, "serving a torn file consults no further fault seams");
    (srv.stop)();
    std::fs::remove_file(&good_path).ok();
    std::fs::remove_file(&torn_path).ok();
}
