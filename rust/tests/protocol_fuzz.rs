//! Seeded fuzz harness for the wire-protocol parser (no deps, mirrors
//! `quant_fuzz.rs`): random byte soup, truncations and single-byte
//! mutations of valid frames, and byte-at-a-time framing via
//! [`split_lines`]. Invariants on every case:
//!
//! * `parse_request` never panics — hostile input reaches the batcher
//!   thread through this function, so a panic here is a remote crash;
//! * every rejection maps to a **documented** error class (the taxonomy
//!   table in `server::protocol`), never an incidental message that a
//!   client could not act on;
//! * `split_lines` only ever fails with the UTF-8 framing diagnostic.
//!
//! Deterministic LCG so every failure reproduces from the case number in
//! the assert message.

use amq::server::protocol::{parse_request, split_lines};

/// Minimal 64-bit LCG (Knuth's MMIX constants) — deterministic, std-only.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// Every error prefix the protocol documents (taxonomy table in
/// `server::protocol`). A parse rejection matching none of these is a bug:
/// either an undocumented failure mode or a typo'd diagnostic.
const DOCUMENTED: &[&str] = &[
    "unknown verb '",
    "malformed session id",
    "malformed max_new",
    "max_new out of range (1..=4096)",
    "malformed token list",
    "GEN needs at least one prime token",
    "SCORE needs at least two tokens",
    "unknown STATS form '",
    "MODEL needs a name",
    "RELOAD needs a model name",
    "unexpected trailing field '",
];

fn assert_documented(case: &str, input: &str) {
    if let Err(e) = parse_request(input) {
        let msg = e.to_string();
        assert!(
            DOCUMENTED.iter().any(|p| msg.starts_with(p)),
            "{case}: undocumented error {msg:?} for input {input:?}"
        );
    }
}

/// Valid frames covering every verb and optional field — the mutation
/// corpus.
const VALID: &[&str] = &[
    "GEN 42 10 1,2,3",
    "GEN 0 1 7 MODEL ptb-2bit",
    "GEN 18446744073709551615 4096 0",
    "SCORE 1,2,3,4,5",
    "SCORE 9,9 MODEL prod",
    "END 7",
    "END 0 MODEL a",
    "STATS",
    "STATS TEXT",
    "RELOAD beta",
    "DRAIN",
    "HEALTH",
];

#[test]
fn drain_and_health_reject_trailing_fields_with_documented_errors() {
    // The zero-argument verbs: any operand is a documented trailing-field
    // rejection, never a silent ignore (a typo'd `DRAIN <model>` must not
    // drain the whole server).
    assert!(amq::server::protocol::parse_request("DRAIN").is_ok());
    assert!(amq::server::protocol::parse_request("HEALTH").is_ok());
    for bad in ["DRAIN now", "HEALTH TEXT", "DRAIN MODEL m", "HEALTH 1"] {
        let msg = amq::server::protocol::parse_request(bad).unwrap_err().to_string();
        assert!(msg.starts_with("unexpected trailing field '"), "{bad}: {msg}");
    }
}

#[test]
fn random_byte_soup_never_panics_and_errors_stay_documented() {
    let mut rng = Lcg(0xf00d);
    // Bytes weighted toward protocol-ish characters so the fuzzer spends
    // its budget near the parser's branches, not deep in "unknown verb".
    const ALPHABET: &[u8] = b"GENSCOREADSTATSRELOADMODELTEXT 0123456789,.-+\t'\\\"\x00\xff\x7f";
    for case in 0..20_000 {
        let len = rng.below(48);
        let raw: Vec<u8> = (0..len)
            .map(|_| {
                if rng.below(8) == 0 {
                    (rng.next() & 0xff) as u8 // occasionally: any byte at all
                } else {
                    ALPHABET[rng.below(ALPHABET.len())]
                }
            })
            .collect();
        let text = String::from_utf8_lossy(&raw).into_owned();
        assert_documented(&format!("soup case {case}"), &text);
    }
}

#[test]
fn truncated_and_mutated_valid_frames_never_panic() {
    // Every truncation of every valid frame.
    for frame in VALID {
        for cut in 0..frame.len() {
            assert_documented(&format!("truncation of {frame:?} at {cut}"), &frame[..cut]);
        }
    }
    // Seeded single-byte mutations (substitute, insert, delete).
    let mut rng = Lcg(0x5eed);
    for case in 0..3_000 {
        let frame = VALID[rng.below(VALID.len())];
        let mut bytes = frame.as_bytes().to_vec();
        match rng.below(3) {
            0 => {
                let i = rng.below(bytes.len());
                bytes[i] = (rng.next() & 0x7f) as u8; // keep it UTF-8
            }
            1 => {
                let i = rng.below(bytes.len() + 1);
                bytes.insert(i, (rng.next() & 0x7f) as u8);
            }
            _ => {
                let i = rng.below(bytes.len());
                bytes.remove(i);
            }
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        assert_documented(&format!("mutation case {case} of {frame:?}"), &text);
    }
}

#[test]
fn split_lines_fuzz_only_fails_with_the_utf8_diagnostic() {
    let mut rng = Lcg(0xbeef);
    for case in 0..2_000 {
        // A soup of bytes fed one at a time — exactly how a trickling or
        // hostile client drives the incremental framer.
        let len = rng.below(96);
        let raw: Vec<u8> = (0..len).map(|_| (rng.next() & 0xff) as u8).collect();
        let mut buf = Vec::new();
        let mut lines = Vec::new();
        let mut rejected = false;
        for &b in &raw {
            buf.push(b);
            match split_lines(&mut buf, &mut lines) {
                Ok(()) => {}
                Err(e) => {
                    assert_eq!(
                        e.to_string(),
                        "request is not UTF-8",
                        "case {case}: framing may only fail on UTF-8"
                    );
                    rejected = true;
                    break;
                }
            }
        }
        if rejected {
            continue;
        }
        // Whatever framed must round-trip into the parser without panics.
        for line in &lines {
            assert_documented(&format!("framed line in case {case}"), line);
        }
    }
}
