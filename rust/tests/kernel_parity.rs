//! Cross-backend exactness contract of the kernel dispatch layer: every
//! available backend (scalar, AVX2, AVX-512 — both arms — and NEON) must
//! reproduce the scalar kernel's f32 outputs with **no tolerance**
//! (`assert_eq!` on f32), for every (method, k_w, k_x, B) grid point —
//! including column counts that are not multiples of 64 (tail words),
//! column counts large enough to engage the SIMD main loops (Harley–Seal
//! blocks on AVX2/AVX-512, the u8-block loop on NEON), batch sizes that
//! are not multiples of the GEMM batch block (partial blocks through the
//! fused primitive), and asymmetric k_w ≠ k_x widths — for every thread
//! count of the execution engine, and for every cache-tiling budget
//! (tiling reorders whole output elements only, so it can never change
//! a bit).
//!
//! Why this can hold exactly: backends only change how the integer
//! mismatch counts `popcount(w ⊕ x)` are computed, and those are exact in
//! any instruction mix; the float reduction is one shared code path in
//! `kernels::binary`. So SIMD here is a pure wall-time optimization —
//! clients can never observe which backend (or how many cores) served
//! them.

use amq::exec::{Exec, ExecConfig};
use amq::kernels::binary::{quantized_gemv, PreparedGemm};
use amq::kernels::Kernel;
use amq::quant::{Method, QuantizedBatch, RowQuantized};
use amq::util::Rng;

/// Shapes: tail words (130, 70), an exact word boundary (64), a column
/// count past the SIMD whole-vector loops (1090 → 18 words per plane),
/// and one long enough to engage the AVX2 Harley–Seal main loop
/// (4109 → 65 words per plane: four 16-word carry-save blocks + a word
/// tail). Large shapes run on the paper's bit widths only (see below).
const SHAPES: [(usize, usize); 5] = [(9, 130), (16, 64), (13, 70), (5, 1090), (3, 4109)];

fn backends_under_test() -> Vec<Kernel> {
    let available = Kernel::available();
    assert!(available.contains(&Kernel::Scalar));
    available
}

/// The full grid of the issue: method × k_w/k_x ∈ {1..4}² × B ∈ {1, 3, 4,
/// 16} × shapes with non-64-multiple cols, every available backend against
/// scalar, zero tolerance.
#[test]
fn gemm_and_gemv_bitmatch_scalar_across_backends_full_grid() {
    let mut rng = Rng::new(0x5EED);
    let methods = [Method::Alternating { t: 2 }, Method::Greedy, Method::Uniform];
    let backends = backends_under_test();
    for method in methods {
        for k_w in 1..=4usize {
            for k_x in 1..=4usize {
                for &(m, n) in &SHAPES {
                    // The big shape only on the paper's bit widths to keep
                    // the grid affordable; small shapes run all 16 combos.
                    if n > 256 && !(k_w == 2 && k_x == 2) {
                        continue;
                    }
                    let w = rng.normal_vec(m * n, 0.3);
                    let wq = RowQuantized::quantize(&w, m, n, k_w, method);
                    let reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
                    for batch in [1usize, 3, 4, 16] {
                        let x = rng.normal_vec(batch * n, 1.0);
                        let xq = QuantizedBatch::quantize(&x, batch, n, k_x);
                        let mut want = vec![0.0f32; batch * m];
                        reference.gemm(&xq, &mut want);
                        for &kernel in &backends {
                            let prep = PreparedGemm::with_kernel(&wq, kernel);
                            let mut got = vec![0.0f32; batch * m];
                            prep.gemm(&xq, &mut got);
                            assert_eq!(
                                got, want,
                                "{kernel} {method:?} k_w={k_w} k_x={k_x} m={m} n={n} B={batch}"
                            );
                        }
                    }
                    // Single-vector path (gemv) on the same operands.
                    let xq = QuantizedBatch::quantize(&rng.normal_vec(n, 1.0), 1, n, k_x);
                    let col = xq.column(0);
                    let mut want = vec![0.0f32; m];
                    reference.gemv(&col, &mut want);
                    for &kernel in &backends {
                        let prep = PreparedGemm::with_kernel(&wq, kernel);
                        let mut got = vec![0.0f32; m];
                        prep.gemv(&col, &mut got);
                        assert_eq!(
                            got, want,
                            "gemv {kernel} {method:?} k_w={k_w} k_x={k_x} m={m} n={n}"
                        );
                    }
                }
            }
        }
    }
}

/// The fused batch-block primitive under batch sizes that are NOT
/// multiples of the driver's block width (GEMM_BLOCK = 4, so B ∈ {1, 3,
/// 5, 7, 17} all end in a partial block) crossed with an asymmetric
/// k_w ≠ k_x grid — the chain-indexing cases of the fused kernel — on
/// every available backend, zero tolerance. Shapes cover the 16-word
/// serving planes (the fused short-plane path), a tail-word shape, and a
/// Harley–Seal-length shape.
#[test]
fn fused_block_partial_batches_and_asymmetric_widths_bitmatch_scalar() {
    let mut rng = Rng::new(0xB10C);
    let backends = backends_under_test();
    for (k_w, k_x) in [(1, 2), (2, 1), (1, 4), (4, 1), (2, 3), (3, 2), (3, 4), (4, 3)] {
        for &(m, n) in &[(8usize, 1024usize), (5, 130), (3, 4109)] {
            // The long shape only on one asymmetric pair per direction to
            // keep the grid affordable.
            if n > 2048 && !matches!((k_w, k_x), (2, 3) | (3, 2)) {
                continue;
            }
            let w = rng.normal_vec(m * n, 0.3);
            let wq = RowQuantized::quantize(&w, m, n, k_w, Method::Alternating { t: 2 });
            let reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
            for batch in [1usize, 3, 5, 7, 17] {
                let x = rng.normal_vec(batch * n, 1.0);
                let xq = QuantizedBatch::quantize(&x, batch, n, k_x);
                let mut want = vec![0.0f32; batch * m];
                reference.gemm(&xq, &mut want);
                for &kernel in &backends {
                    let prep = PreparedGemm::with_kernel(&wq, kernel);
                    let mut got = vec![0.0f32; batch * m];
                    prep.gemm(&xq, &mut got);
                    assert_eq!(
                        got, want,
                        "{kernel} k_w={k_w} k_x={k_x} m={m} n={n} B={batch}"
                    );
                }
            }
        }
    }
}

/// Both AVX-512 arms — native `vpopcntq` and the 512-bit LUT +
/// Harley–Seal fallback — must produce the exact integer mismatch counts
/// of an independent scalar popcount, over the full grid: k_w/k_x ∈
/// {1..4}², batch blocks that end in partial GEMM blocks (B ∈ {1, 3, 5,
/// 7, 17}), and plane lengths covering single words, the 8-word vector
/// boundary, vector tails, the Harley–Seal threshold (63/64/65 words),
/// and a long multi-block length (130). Each arm runs through the
/// `#[doc(hidden)]` test hook so the LUT arm is exercised even on
/// `vpopcntdq` hardware; an arm the host lacks is skipped with a notice
/// (the hook returns `false`), never silently passed.
#[test]
fn avx512_both_arms_bitmatch_scalar_at_count_level() {
    use amq::kernels::backend::testing::avx512_block_counts_arm;
    let mut rng = Rng::new(0xA512);
    for arm in ["vpopcntq", "lut"] {
        // One-shot availability probe on a trivial block; the hook leaves
        // counts untouched and returns false when the host lacks the arm.
        let probe = [0u64; 1];
        let pw: [&[u64]; 1] = [&probe];
        let pc: [&[u64]; 1] = [&probe];
        let pb: [&[&[u64]]; 1] = [&pc];
        if !avx512_block_counts_arm(arm, &pw, &pb, &mut [0u32; 1]) {
            eprintln!(
                "notice: host cannot run the avx512 {arm} arm — skipping its count-parity grid"
            );
            continue;
        }
        for words in [1usize, 2, 7, 8, 9, 16, 63, 64, 65, 130] {
            for k_w in 1..=4usize {
                for k_x in 1..=4usize {
                    // Long planes only at the paper's widths to keep the
                    // grid affordable; short planes run all 16 combos.
                    if words > 16 && !(k_w == 2 && k_x == 2) {
                        continue;
                    }
                    for batch in [1usize, 3, 5, 7, 17] {
                        let wplanes: Vec<Vec<u64>> = (0..k_w)
                            .map(|_| (0..words).map(|_| rng.next_u64()).collect())
                            .collect();
                        let xplanes: Vec<Vec<u64>> = (0..batch * k_x)
                            .map(|_| (0..words).map(|_| rng.next_u64()).collect())
                            .collect();
                        let w: Vec<&[u64]> = wplanes.iter().map(|p| &p[..]).collect();
                        let cols: Vec<Vec<&[u64]>> = (0..batch)
                            .map(|j| (0..k_x).map(|s| &xplanes[j * k_x + s][..]).collect())
                            .collect();
                        let x_block: Vec<&[&[u64]]> = cols.iter().map(|c| &c[..]).collect();
                        // Independent reference: plain u64 xor + count_ones.
                        let mut want = vec![0u32; batch * k_w * k_x];
                        for (j, col) in cols.iter().enumerate() {
                            for (t, wp) in wplanes.iter().enumerate() {
                                for (s, xp) in col.iter().enumerate() {
                                    want[(j * k_w + t) * k_x + s] = wp
                                        .iter()
                                        .zip(xp.iter())
                                        .map(|(&a, &b)| (a ^ b).count_ones())
                                        .sum();
                                }
                            }
                        }
                        let mut got = vec![0u32; batch * k_w * k_x];
                        assert!(
                            avx512_block_counts_arm(arm, &w, &x_block, &mut got),
                            "arm {arm} disappeared mid-grid"
                        );
                        assert_eq!(
                            got, want,
                            "avx512({arm}) k_w={k_w} k_x={k_x} words={words} B={batch}"
                        );
                    }
                }
            }
        }
    }
}

/// Column tiling must never change a bit: the batched GEMM run with a
/// tiny L2 budget (many tiles), a huge one (a single tile), and the
/// detected default must produce identical f32 outputs on every
/// available backend — including batch sizes that do not divide evenly
/// into any tile. This is the `AMQ_L2_KB ∈ {tiny, huge}` contract of the
/// tiling layer, driven through the per-instance budget override.
#[test]
fn tiled_gemm_bitmatches_untiled_across_budgets_and_backends() {
    let mut rng = Rng::new(0x7113D);
    let (m, n, k) = (17, 1090, 2);
    let w = rng.normal_vec(m * n, 0.3);
    let wq = RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 });
    for batch in [1usize, 5, 17, 64] {
        let x = rng.normal_vec(batch * n, 1.0);
        let xq = QuantizedBatch::quantize(&x, batch, n, k);
        // Untiled reference: scalar backend, one tile covering the batch.
        let mut reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
        reference.set_l2_budget(usize::MAX);
        let mut want = vec![0.0f32; batch * m];
        reference.gemm(&xq, &mut want);
        for kernel in backends_under_test() {
            for budget in [1usize, 64 * 1024, usize::MAX] {
                let mut prep = PreparedGemm::with_kernel(&wq, kernel);
                prep.set_l2_budget(budget);
                let mut got = vec![0.0f32; batch * m];
                prep.gemm(&xq, &mut got);
                assert_eq!(got, want, "{kernel} budget={budget} B={batch}");
                // And under the threaded driver at the same budget.
                let exec = Exec::new(ExecConfig::with_threads(3));
                let mut got_t = vec![0.0f32; batch * m];
                prep.gemm_exec(&xq, &mut got_t, &exec);
                assert_eq!(got_t, want, "{kernel} budget={budget} B={batch} threaded");
            }
        }
    }
}

/// When `AMQ_KERNEL` is set (the per-backend CI legs), it must name a
/// backend this host can run, and that backend must actually be the
/// active one — a forced leg that silently fell back to detection or
/// scalar would be testing the wrong kernel. This is what makes the
/// `AMQ_KERNEL=avx2` CI leg fail loudly on a runner without AVX2.
#[test]
fn forced_env_kernel_is_available_and_active() {
    let Ok(v) = std::env::var("AMQ_KERNEL") else {
        return; // no forced leg — nothing to pin
    };
    let choice = Kernel::parse_choice(&v).unwrap_or_else(|e| {
        panic!("AMQ_KERNEL={v} does not name a backend this host can run: {e}")
    });
    if let Some(kernel) = choice {
        assert_eq!(
            amq::kernels::backend::active(),
            kernel,
            "AMQ_KERNEL={v} was not the active backend"
        );
    }
}

/// Backend parity must also hold under the row-sharded threaded GEMM:
/// (backend × thread count) never changes a bit.
#[test]
fn threaded_gemm_bitmatches_serial_scalar_across_backends() {
    let mut rng = Rng::new(0xACE5);
    let (m, n, k, batch) = (11, 1100, 2, 8);
    let w = rng.normal_vec(m * n, 0.3);
    let wq = RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 });
    let x = rng.normal_vec(batch * n, 1.0);
    let xq = QuantizedBatch::quantize(&x, batch, n, k);
    let reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
    let mut want = vec![0.0f32; batch * m];
    reference.gemm(&xq, &mut want);
    for kernel in backends_under_test() {
        let prep = PreparedGemm::with_kernel(&wq, kernel);
        for threads in [1usize, 2, 3, 8] {
            let exec = Exec::new(ExecConfig::with_threads(threads));
            let mut got = vec![0.0f32; batch * m];
            prep.gemm_exec(&xq, &mut got, &exec);
            assert_eq!(got, want, "{kernel} threads={threads}");
        }
    }
}

/// The legacy `RowQuantized` entry point (`quantized_gemv`, the trainer's
/// path) routes through the same backend dispatch: whatever backend is
/// active for this process, it must bit-match the scalar `PreparedGemm`.
#[test]
fn legacy_quantized_gemv_bitmatches_scalar_prepared() {
    let mut rng = Rng::new(0xFACE5);
    for (m, n, k_w, k_x) in [(9, 1090, 2, 2), (6, 70, 3, 2), (4, 130, 4, 4), (3, 64, 1, 1)] {
        let w = rng.normal_vec(m * n, 0.3);
        let wq = RowQuantized::quantize(&w, m, n, k_w, Method::Alternating { t: 2 });
        let xq = QuantizedBatch::quantize(&rng.normal_vec(n, 1.0), 1, n, k_x).column(0);
        let mut legacy = vec![0.0f32; m];
        quantized_gemv(&wq, &xq, &mut legacy);
        let reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
        let mut want = vec![0.0f32; m];
        reference.gemv(&xq, &mut want);
        assert_eq!(legacy, want, "m={m} n={n} k_w={k_w} k_x={k_x}");
    }
}

/// Online quantization + GEMM end-to-end across backends (the serving
/// request path), bit-exact against scalar.
#[test]
fn online_gemm_bitmatches_scalar_across_backends() {
    let mut rng = Rng::new(0xBEEF5);
    let (m, n, k, batch) = (10, 1100, 2, 4);
    let w = rng.normal_vec(m * n, 0.3);
    let wq = RowQuantized::quantize(&w, m, n, k, Method::Alternating { t: 2 });
    let x = rng.normal_vec(batch * n, 1.0);
    let reference = PreparedGemm::with_kernel(&wq, Kernel::Scalar);
    let mut want = vec![0.0f32; batch * m];
    reference.online_gemm(&x, batch, k, &mut want);
    for kernel in backends_under_test() {
        let prep = PreparedGemm::with_kernel(&wq, kernel);
        let mut got = vec![0.0f32; batch * m];
        prep.online_gemm(&x, batch, k, &mut got);
        assert_eq!(got, want, "{kernel}");
    }
}
