//! Exact-parity contract of the batch-first forward API: batching must be
//! invisible — every batched path bit-matches its per-vector counterpart,
//! with **no tolerance**. This is what lets the server's dynamic batcher
//! group arbitrary sessions without changing any client-visible token.

use amq::kernels::binary::PreparedGemm;
use amq::model::batch::ActivationBatch;
use amq::model::gru::GruCell;
use amq::model::linear::Precision;
use amq::model::lm::{LmConfig, LmState, PrecisionPolicy, RnnKind, RnnLm};
use amq::model::lstm::{LstmCell, LstmState, LstmStateBatch};
use amq::quant::{Method, QuantizedBatch, RowQuantized};
use amq::util::Rng;

/// `PreparedGemm::gemm` bit-matches `PreparedGemm::gemv` (the PreparedGemv
/// path) column by column for every paper bit-width pairing.
#[test]
fn prepared_gemm_bitmatches_gemv_all_bitwidths() {
    let mut rng = Rng::new(7001);
    for k_w in 1..=3 {
        for k_a in 1..=3 {
            for batch in 1..=4 {
                let (m, n) = (19, 147); // odd shapes exercise tail words
                let w = rng.normal_vec(m * n, 0.3);
                let prep = PreparedGemm::new(&RowQuantized::quantize(
                    &w,
                    m,
                    n,
                    k_w,
                    Method::Alternating { t: 2 },
                ));
                let x = rng.normal_vec(batch * n, 1.0);
                let xq = QuantizedBatch::quantize(&x, batch, n, k_a);
                let mut y = vec![0.0f32; batch * m];
                prep.gemm(&xq, &mut y);
                for b in 0..batch {
                    let mut yb = vec![0.0f32; m];
                    prep.gemv(&xq.column(b), &mut yb);
                    assert_eq!(
                        &y[b * m..(b + 1) * m],
                        &yb[..],
                        "k_w={k_w} k_a={k_a} batch={batch} col={b}"
                    );
                }
            }
        }
    }
}

/// `LstmCell::step_batch` with B = 1..=4 bit-matches per-vector `step`.
#[test]
fn lstm_step_batch_bitmatches_step() {
    let mut rng = Rng::new(7002);
    for precision in [
        Precision::Full,
        Precision::Quantized { k_w: 2, k_a: 2 },
        Precision::Quantized { k_w: 3, k_a: 3 },
    ] {
        let cell = LstmCell::init(24, 32, 0.3, &mut rng, precision);
        for batch in 1..=4 {
            let states: Vec<LstmState> = (0..batch)
                .map(|_| LstmState { h: rng.normal_vec(32, 0.5), c: rng.normal_vec(32, 0.5) })
                .collect();
            let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(24, 1.0)).collect();
            let refs: Vec<&LstmState> = states.iter().collect();
            let xrows: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let next = cell.step_batch(
                &ActivationBatch::from_rows(&xrows),
                &LstmStateBatch::from_states(&refs),
            );
            for b in 0..batch {
                let expect = cell.step(&xs[b], &states[b]);
                assert_eq!(next.state(b), expect, "{precision:?} B={batch} col={b}");
            }
        }
    }
}

/// `GruCell::step_batch` with B = 1..=4 bit-matches per-vector `step`.
#[test]
fn gru_step_batch_bitmatches_step() {
    let mut rng = Rng::new(7003);
    for precision in [
        Precision::Full,
        Precision::Quantized { k_w: 2, k_a: 2 },
        Precision::Quantized { k_w: 3, k_a: 3 },
    ] {
        let cell = GruCell::init(24, 32, 0.3, &mut rng, precision);
        for batch in 1..=4 {
            let hs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(32, 0.5)).collect();
            let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(24, 1.0)).collect();
            let hrows: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let xrows: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let next = cell.step_batch(
                &ActivationBatch::from_rows(&xrows),
                &ActivationBatch::from_rows(&hrows),
            );
            for b in 0..batch {
                let expect = cell.step(&xs[b], &hs[b]);
                assert_eq!(next.row(b), &expect[..], "{precision:?} B={batch} col={b}");
            }
        }
    }
}

/// Whole-model parity over multiple timesteps, both cell kinds, quantized
/// end to end (embedding prequant rows included).
#[test]
fn lm_step_batch_bitmatches_step_over_time() {
    for kind in [RnnKind::Lstm, RnnKind::Gru] {
        let lm = RnnLm::random(
            LmConfig { kind, vocab: 80, hidden: 40, layers: 1 },
            7004,
            PrecisionPolicy::quantized(2, 2),
        );
        let batch = 4;
        let mut singles: Vec<LmState> = (0..batch).map(|_| lm.zero_state()).collect();
        let mut batched = lm.zero_state_batch(batch);
        for round in 0..5 {
            let tokens: Vec<usize> = (0..batch).map(|b| (11 * b + 29 * round + 3) % 80).collect();
            let logits = lm.step_batch(&tokens, &mut batched);
            for b in 0..batch {
                let expect = lm.step(tokens[b], &mut singles[b]);
                assert_eq!(logits.row(b), &expect[..], "{kind:?} round={round} col={b}");
            }
        }
        assert_eq!(lm.scatter_states(&batched), singles, "{kind:?} final states");
    }
}
