//! Workspace-reuse parity + the zero-allocation gate.
//!
//! Two contracts of the `_into` serving path:
//!
//! 1. **Parity**: every `_into` API writing into reused (dirty) buffers is
//!    bit-identical to its allocating wrapper with fresh buffers, across
//!    (method × bit width × batch × threads) — including reuse across
//!    *changing* shapes, the stale-state failure mode fresh-buffer tests
//!    cannot see.
//! 2. **Zero allocation**: a warmed-up steady-state
//!    `RnnLm::step_batch_into_exec` timestep (LSTM, W2A2, B ∈ {1, 16})
//!    performs **no heap allocation** on the serial engine.
//!
//! The whole binary runs under the shared counting `#[global_allocator]`
//! (`rust/tests/support/counting_alloc.rs` — thread-local counters, so
//! concurrently running harness tests never pollute a measured window;
//! this suite doubles as the "test run with the counting allocator
//! enabled" CI leg).

#[path = "support/counting_alloc.rs"]
mod counting_alloc;

use amq::exec::{Exec, ExecConfig};
use amq::model::linear::{Linear, LinearOp, LinearWorkspace, Precision};
use amq::model::lm::{LmConfig, LmStepWorkspace, PrecisionPolicy, RnnKind, RnnLm};
use amq::model::ActivationBatch;
use amq::model::OutputBatch;
use amq::quant::{alternating, greedy, Method, QuantScratch, QuantizedBatch};
use amq::util::Rng;
use counting_alloc::thread_alloc_counts;

fn tiny(kind: RnnKind) -> LmConfig {
    LmConfig { kind, vocab: 50, hidden: 24, layers: 1 }
}

/// The fused quantizer cores against their allocating wrappers, with one
/// dirty scratch reused across every shape.
#[test]
fn quantizer_into_cores_match_allocating_wrappers() {
    let mut rng = Rng::new(0xF00D);
    let mut scratch = QuantScratch::new();
    for n in [1usize, 63, 64, 70, 130] {
        for k in 1..=4 {
            let w = rng.normal_vec(n, 0.5);
            let wpp = n.div_ceil(64);
            let mut alphas = vec![9.9f32; k];
            let mut words = vec![u64::MAX; k * wpp];
            greedy::quantize_into(&w, k, &mut alphas, &mut words, &mut scratch);
            let q = greedy::quantize(&w, k);
            assert_eq!(alphas, q.alphas, "greedy n={n} k={k}");
            for (t, p) in q.planes.iter().enumerate() {
                assert_eq!(&words[t * wpp..(t + 1) * wpp], p.words(), "greedy n={n} k={k} t={t}");
            }
            alternating::quantize_into(&w, k, 2, &mut alphas, &mut words, &mut scratch);
            let q = alternating::quantize(&w, k, 2);
            assert_eq!(alphas, q.alphas, "alternating n={n} k={k}");
            for (t, p) in q.planes.iter().enumerate() {
                assert_eq!(
                    &words[t * wpp..(t + 1) * wpp],
                    p.words(),
                    "alternating n={n} k={k} t={t}"
                );
            }
        }
    }
}

/// `QuantizedBatch::quantize_into_exec` on one reused batch + scratch set
/// vs a fresh quantization: (method × k ∈ 1..4 × B ∈ {1,3,16} ×
/// threads ∈ {1,4}), shapes deliberately shrinking and growing between
/// calls so stale buffer contents would be caught.
#[test]
fn quantized_batch_into_matches_allocating_across_grid() {
    let mut rng = Rng::new(0xA110C);
    let methods = [Method::Greedy, Method::Alternating { t: 2 }, Method::Uniform, Method::Ternary];
    let mut reused = QuantizedBatch::empty();
    let mut scratches: Vec<QuantScratch> = Vec::new();
    for threads in [1usize, 4] {
        let exec = Exec::new(ExecConfig::with_threads(threads));
        for method in methods {
            for k in 1..=4 {
                for batch in [16usize, 1, 3] {
                    let n = 70;
                    let x = rng.normal_vec(batch * n, 0.8);
                    let want = QuantizedBatch::quantize_with_exec(&x, batch, n, k, method, &exec);
                    let tasks = exec.threads().min(batch).max(1);
                    if scratches.len() < tasks {
                        scratches.resize_with(tasks, QuantScratch::default);
                    }
                    reused.quantize_into_exec(&x, batch, n, k, method, &exec, &mut scratches);
                    let tag = format!("{method:?} k={k} B={batch} threads={threads}");
                    assert_eq!(reused.batch, want.batch, "{tag}");
                    assert_eq!(reused.k, want.k, "{tag}");
                    assert_eq!(reused.words_per_plane, want.words_per_plane, "{tag}");
                    assert_eq!(reused.alphas, want.alphas, "{tag}");
                    assert_eq!(reused.data, want.data, "{tag}");
                }
            }
        }
    }
}

/// Linear-layer `_into` forwards (dense + quantized, online + prequant)
/// against the allocating forwards, one workspace reused throughout.
#[test]
fn linear_forward_into_matches_forward() {
    let mut rng = Rng::new(0xBEAD);
    let (m, n) = (18, 75);
    let wv = rng.normal_vec(m * n, 0.3);
    for layer in [
        Linear::new(wv.clone(), m, n, Precision::Full),
        Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 2, k_a: 2 }),
        Linear::new(wv.clone(), m, n, Precision::Quantized { k_w: 3, k_a: 2 }),
    ] {
        let mut ws = LinearWorkspace::new();
        let mut y_into = OutputBatch::zeros(0, 0);
        for threads in [1usize, 4] {
            let exec = Exec::new(ExecConfig::with_threads(threads));
            for batch in [5usize, 1, 16, 3] {
                let x = rng.normal_vec(batch * n, 1.0);
                let xb = ActivationBatch::from_flat(x, batch, n);
                let mut want = OutputBatch::zeros(batch, m);
                layer.forward_exec(&xb, &mut want, &exec);
                layer.forward_into_exec(&xb, &mut y_into, &exec, &mut ws);
                assert_eq!(y_into.data(), want.data(), "batch={batch} threads={threads}");
                let xq = xb.quantize(2);
                let mut wantq = OutputBatch::zeros(batch, m);
                layer.forward_prequant_exec(&xq, &mut wantq, &exec);
                layer.forward_prequant_into_exec(&xq, &mut y_into, &exec, &mut ws);
                assert_eq!(y_into.data(), wantq.data(), "prequant batch={batch}");
            }
        }
    }
}

/// Whole-model parity: `step_batch_into_exec` with one workspace reused
/// across rounds, batch sizes, and bit widths vs the allocating
/// `step_batch_exec`, for both cell kinds and threads ∈ {1, 4}. States
/// must stay equal step by step (the double-buffer swap must not corrupt
/// or stale-read anything).
#[test]
fn model_step_into_matches_allocating_step() {
    for kind in [RnnKind::Lstm, RnnKind::Gru] {
        for k in 1..=4 {
            let lm = RnnLm::random(tiny(kind), 11 + k as u64, PrecisionPolicy::quantized(k, k));
            for threads in [1usize, 4] {
                let exec = Exec::new(ExecConfig::with_threads(threads));
                let mut ws = LmStepWorkspace::new();
                let mut logits_into = OutputBatch::zeros(0, 0);
                for batch in [16usize, 1, 3] {
                    let mut sa = lm.zero_state_batch(batch);
                    let mut sb = lm.zero_state_batch(batch);
                    for round in 0..3 {
                        let tokens: Vec<usize> =
                            (0..batch).map(|b| (5 * b + 7 * round + k) % 50).collect();
                        let want = lm.step_batch_exec(&tokens, &mut sa, &exec);
                        lm.step_batch_into_exec(&tokens, &mut sb, &mut logits_into, &exec, &mut ws);
                        let tag = format!("{kind:?} k={k} B={batch} t={threads} round={round}");
                        assert_eq!(logits_into.data(), want.data(), "{tag}");
                        assert_eq!(sa, sb, "{tag}");
                    }
                }
            }
        }
    }
}

/// Full-precision models ride the same `_into` path (dense embedding +
/// dense layers) — parity there too.
#[test]
fn full_precision_model_step_into_matches_allocating_step() {
    for kind in [RnnKind::Lstm, RnnKind::Gru] {
        let lm = RnnLm::random(tiny(kind), 29, PrecisionPolicy::full());
        let exec = Exec::serial();
        let mut ws = LmStepWorkspace::new();
        let mut logits_into = OutputBatch::zeros(0, 0);
        for batch in [4usize, 1] {
            let mut sa = lm.zero_state_batch(batch);
            let mut sb = lm.zero_state_batch(batch);
            for round in 0..3 {
                let tokens: Vec<usize> = (0..batch).map(|b| (3 * b + round + 1) % 50).collect();
                let want = lm.step_batch_exec(&tokens, &mut sa, &exec);
                lm.step_batch_into_exec(&tokens, &mut sb, &mut logits_into, &exec, &mut ws);
                assert_eq!(logits_into.data(), want.data(), "{kind:?} B={batch} round={round}");
                assert_eq!(sa, sb, "{kind:?} B={batch} round={round}");
            }
        }
    }
}

/// Gather/scatter `_into` round trip on reused buffers matches the
/// allocating gather/scatter.
#[test]
fn gather_scatter_into_matches_allocating() {
    for kind in [RnnKind::Lstm, RnnKind::Gru] {
        let lm = RnnLm::random(tiny(kind), 31, PrecisionPolicy::quantized(2, 2));
        let mut singles: Vec<_> = (0..5).map(|_| lm.zero_state()).collect();
        for (i, s) in singles.iter_mut().enumerate() {
            lm.step(i % 50, s);
        }
        let refs: Vec<&_> = singles.iter().collect();
        let want = lm.gather_states(&refs);
        let mut reused = lm.zero_state_batch(2); // wrong size: must resize
        lm.gather_states_into(&refs, &mut reused);
        assert_eq!(reused, want, "{kind:?}");
        let scattered = lm.scatter_states(&want);
        for (b, s) in scattered.iter().enumerate() {
            let mut out = lm.zero_state();
            lm.scatter_state_into(&want, b, &mut out);
            assert_eq!(&out, s, "{kind:?} col {b}");
        }
    }
}

/// The acceptance gate: a warmed-up steady-state decode timestep through
/// `step_batch_into_exec` (LSTM, W2A2, B ∈ {1, 16}, serial engine)
/// performs ZERO heap allocations — counted by the global allocator on
/// this thread only.
#[test]
fn steady_state_decode_is_allocation_free() {
    let lm = RnnLm::random(tiny(RnnKind::Lstm), 9, PrecisionPolicy::quantized(2, 2));
    let exec = Exec::serial();
    for batch in [1usize, 16] {
        let mut state = lm.zero_state_batch(batch);
        let mut ws = LmStepWorkspace::new();
        let mut logits = OutputBatch::zeros(0, 0);
        let mut tokens: Vec<usize> = (0..batch).map(|b| (7 * b + 1) % 50).collect();
        // Warm up: every buffer grows to its steady-state capacity.
        for round in 0..3usize {
            lm.step_batch_into_exec(&tokens, &mut state, &mut logits, &exec, &mut ws);
            for (b, t) in tokens.iter_mut().enumerate() {
                *t = (*t + 11 * b + round + 1) % 50;
            }
        }
        let (a0, by0) = thread_alloc_counts();
        for round in 0..5usize {
            lm.step_batch_into_exec(&tokens, &mut state, &mut logits, &exec, &mut ws);
            for (b, t) in tokens.iter_mut().enumerate() {
                *t = (*t + 3 * b + round + 1) % 50;
            }
        }
        let (a1, by1) = thread_alloc_counts();
        assert_eq!(
            (a1 - a0, by1 - by0),
            (0, 0),
            "B={batch}: steady-state step_batch_into_exec allocated"
        );
    }
}

/// Same gate one level down: a warmed `QuantizedBatch::quantize_into_exec`
/// re-quantizing a fresh activation batch every "timestep" allocates
/// nothing on the serial engine.
#[test]
fn steady_state_batch_quantization_is_allocation_free() {
    let mut rng = Rng::new(0x5EED);
    let (batch, n, k) = (16usize, 96usize, 2usize);
    let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(batch * n, 0.8)).collect();
    let exec = Exec::serial();
    let mut qb = QuantizedBatch::empty();
    let mut scratches = vec![QuantScratch::new()];
    let method = Method::Alternating { t: 2 };
    // Warm up.
    qb.quantize_into_exec(&xs[0], batch, n, k, method, &exec, &mut scratches);
    let (a0, _) = thread_alloc_counts();
    for x in &xs {
        qb.quantize_into_exec(x, batch, n, k, method, &exec, &mut scratches);
    }
    let (a1, _) = thread_alloc_counts();
    assert_eq!(a1 - a0, 0, "steady-state quantize_into_exec allocated");
}
