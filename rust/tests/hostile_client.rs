//! Malicious-client integration suite over real TCP, against BOTH front
//! ends (thread-per-connection grouped batcher, event-loop continuous
//! batcher). A hostile peer must get `ERR` lines — never a panic, never a
//! wedged server — and well-formed sessions running concurrently must
//! produce bit-exact output throughout.
//!
//! Covered classes (see the taxonomy table in `server::protocol`):
//! out-of-vocab tokens in `GEN`/`SCORE` (the remote-panic bug: these used
//! to reach `Embedding::lookup`'s assert on the batcher thread), trailing
//! garbage after every verb, a bare `MODEL` field, unknown model names,
//! the oversized-line framing guard (including the bypass where a valid
//! pipelined line used to disarm it), and non-UTF-8 bytes.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::Duration;

use amq::exec::ExecConfig;
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Work};
use amq::server::protocol::MAX_LINE;
use amq::server::tcp;

const VOCAB: usize = 40;

fn model() -> Arc<RnnLm> {
    Arc::new(RnnLm::random(
        LmConfig { kind: RnnKind::Lstm, vocab: VOCAB, hidden: 16, layers: 1 },
        5,
        PrecisionPolicy::quantized(2, 2),
    ))
}

fn connect(addr: SocketAddr) -> TcpStream {
    let conn = TcpStream::connect(addr).expect("connect");
    // A wedged or panicked server must fail the test quickly, not hang it.
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    conn
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).expect("server reply");
    line.trim_end().to_string()
}

/// One request on a fresh connection; returns the single reply line.
fn one_shot(addr: SocketAddr, line: &str) -> String {
    let mut conn = connect(addr);
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    read_line(&mut BufReader::new(conn))
}

/// The whole hostile battery against one live front end.
fn suite(addr: SocketAddr) {
    // Ground truth from a fresh session, before any hostile traffic.
    let baseline = one_shot(addr, "GEN 500 6 3,4");
    assert!(baseline.starts_with("OK GEN "), "{baseline}");

    // A well-formed client races the hostile one; its fresh session must
    // produce exactly the baseline tokens no matter what the abuse does.
    let concurrent = std::thread::spawn(move || one_shot(addr, "GEN 501 6 3,4"));

    // --- One pipelined burst of malformed + hostile + valid requests. ---
    let mut conn = connect(addr);
    conn.write_all(
        b"GEN 1 10 1,2 9,9\n\
          END 3 junk\n\
          STATS TEXT x\n\
          GEN 1 10 1,2 MODEL\n\
          SCORE 1,999\n\
          GEN 2 4 2,999,3\n\
          GEN 3 3 1 MODEL nope\n\
          SCORE 1,2 MODEL nope\n\
          GEN 600 3 5 MODEL default\n",
    )
    .unwrap();
    let mut r = BufReader::new(conn);
    assert_eq!(read_line(&mut r), "ERR unexpected trailing field '9,9'");
    assert_eq!(read_line(&mut r), "ERR unexpected trailing field 'junk'");
    assert_eq!(read_line(&mut r), "ERR unexpected trailing field 'x'");
    assert_eq!(read_line(&mut r), "ERR MODEL needs a name");
    assert_eq!(read_line(&mut r), format!("ERR token 999 out of vocab {VOCAB}"));
    assert_eq!(read_line(&mut r), format!("ERR token 999 out of vocab {VOCAB}"));
    assert_eq!(read_line(&mut r), "ERR unknown model 'nope'");
    assert_eq!(read_line(&mut r), "ERR unknown model 'nope'");
    let ok = read_line(&mut r);
    assert!(ok.starts_with("OK GEN "), "valid request after the abuse must serve: {ok}");
    assert_eq!(ok.trim_start_matches("OK GEN ").split(',').count(), 3, "{ok}");
    drop(r);

    // --- Framing guard: a valid pipelined line must NOT disarm it. ---
    let conn = connect(addr);
    let mut w = conn.try_clone().unwrap();
    let writer = std::thread::spawn(move || {
        // The server closes mid-write once the tail passes MAX_LINE;
        // EPIPE here is expected.
        let mut payload = b"STATS\n".to_vec();
        payload.extend_from_slice(&vec![b'x'; MAX_LINE + 16 * 1024]);
        let _ = w.write_all(&payload);
    });
    let mut r = BufReader::new(conn);
    let stats = read_line(&mut r);
    assert!(stats.starts_with("OK STATS {"), "pipelined STATS still answers: {stats}");
    assert_eq!(read_line(&mut r), "ERR request line exceeds MAX_LINE");
    let mut rest = Vec::new();
    assert_eq!(
        r.read_to_end(&mut rest).expect("clean close"),
        0,
        "connection must close after a framing error"
    );
    writer.join().unwrap();

    // --- Non-UTF-8 bytes: diagnostic, then close. ---
    let mut conn = connect(addr);
    conn.write_all(b"\xff\xfe junk\n").unwrap();
    let mut r = BufReader::new(conn);
    assert_eq!(read_line(&mut r), "ERR request is not UTF-8");
    let mut rest = Vec::new();
    assert_eq!(r.read_to_end(&mut rest).expect("clean close"), 0);

    // --- Durability verbs under abuse: trailing operands reject with the
    // documented taxonomy, a DRAIN on a server with no snapshot path
    // refuses without wedging admission, and HEALTH answers front-end-side.
    assert_eq!(one_shot(addr, "DRAIN now"), "ERR unexpected trailing field 'now'");
    assert_eq!(one_shot(addr, "HEALTH TEXT"), "ERR unexpected trailing field 'TEXT'");
    assert_eq!(
        one_shot(addr, "DRAIN"),
        "ERR DRAINING no snapshot path configured (start with --snapshot <path>)"
    );
    let health = one_shot(addr, "HEALTH");
    assert!(health.starts_with("OK HEALTH ok uptime="), "unarmed DRAIN must not flip: {health}");

    // The concurrent well-formed session was bit-exact throughout.
    assert_eq!(concurrent.join().unwrap(), baseline, "hostile traffic must not perturb decode");

    // The server survived everything: new connections serve, STATS counts
    // the errors, and a fresh session still bit-matches the baseline.
    let stats = one_shot(addr, "STATS");
    assert!(stats.starts_with("OK STATS {"), "{stats}");
    assert!(stats.contains("\"errors\":"), "{stats}");
    assert!(stats.contains("\"health\":\"ok\""), "{stats}");
    assert!(stats.contains("\"drains\":0"), "refused drains must not count: {stats}");
    assert_eq!(one_shot(addr, "GEN 502 6 3,4"), baseline);
}

#[test]
fn hostile_clients_get_errors_not_panics_thread_per_conn() {
    let server = InferenceServer::new(
        model(),
        BatcherConfig { max_batch: 4, exec: ExecConfig::serial(), ..Default::default() },
    );
    let health = server.health.clone();
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = shutdown.clone();
    let (addr_tx, addr_rx) = mpsc::channel();
    let tx2: Sender<Work> = tx.clone();
    let srv = std::thread::spawn(move || {
        tcp::serve_with_health("127.0.0.1:0", tx2, flag, Some(health), move |a| {
            let _ = addr_tx.send(a);
        })
    });
    let addr = addr_rx.recv().unwrap();

    suite(addr);

    shutdown.store(true, Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
}

#[cfg(unix)]
#[test]
fn hostile_clients_get_errors_not_panics_event_loop() {
    use amq::server::eventloop::{self, EventLoopConfig};
    let server = InferenceServer::new(
        model(),
        BatcherConfig {
            max_batch: 4,
            continuous: true,
            max_slots: 4,
            queue_depth: 64,
            exec: ExecConfig::serial(),
            ..Default::default()
        },
    );
    let health = server.health.clone();
    let (tx, rx) = mpsc::channel::<Work>();
    let batcher = std::thread::spawn(move || server.run(rx));
    let cfg = EventLoopConfig { loops: 2, health: Some(health), ..Default::default() };
    let srv = eventloop::serve("127.0.0.1:0", tx.clone(), cfg).expect("event-loop bind");

    suite(srv.addr);

    srv.shutdown();
    tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
}
