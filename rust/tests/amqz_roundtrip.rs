//! The `.amqz` packed-model format, end to end: save → load must be
//! bit-identical to the in-memory model it came from (ppw and greedy
//! decode compared to the bit), cold-loading must beat rebuilding by the
//! ≥5× the format exists for, and a budgeted [`ModelRegistry`] must
//! hot-swap three published models through the batcher with LRU evictions
//! while every reply still bit-matches its model's single-tenant output.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use amq::data::amqz;
use amq::exec::{Exec, ExecConfig};
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Reply, Request, Respond, Work};
use amq::server::ModelRegistry;

fn temp_amqz(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("amqz_test_{}_{tag}.amqz", std::process::id()))
}

/// Greedy decode on a fresh single-tenant grouped server: the reference
/// every loaded/registry-served model must bit-match.
fn generate(model: Arc<RnnLm>, prime: &[usize], max_new: usize) -> Vec<usize> {
    let mut server = InferenceServer::new(
        model,
        BatcherConfig { max_batch: 1, exec: ExecConfig::serial(), ..Default::default() },
    );
    let (tx, rx) = mpsc::channel();
    server.process_batch(vec![Request {
        session: 1,
        max_new,
        prime: prime.to_vec(),
        model: None,
        respond: Respond::Channel(tx),
        enqueued: Instant::now(),
    }]);
    match rx.recv().unwrap() {
        Reply::Gen(resp) => resp.tokens,
        other => panic!("unexpected reply {other:?}"),
    }
}

#[test]
fn packed_roundtrip_is_bit_identical() {
    for (kind, tag) in [(RnnKind::Lstm, "lstm"), (RnnKind::Gru, "gru")] {
        let config = LmConfig { kind, vocab: 120, hidden: 32, layers: 2 };
        let original = Arc::new(RnnLm::random(config, 42, PrecisionPolicy::quantized(2, 2)));
        let path = temp_amqz(tag);
        amqz::save(&path, &original.to_packed().unwrap()).unwrap();
        let loaded = Arc::new(amqz::load_model(&path).unwrap());
        std::fs::remove_file(&path).ok();

        assert_eq!(loaded.bytes(), original.bytes(), "{tag}: packed sizes diverge");
        let tokens: Vec<usize> = (0..40).map(|i| (i * 7 + 3) % 120).collect();
        assert_eq!(
            loaded.ppw(&tokens).to_bits(),
            original.ppw(&tokens).to_bits(),
            "{tag}: scoring must be bit-identical after a roundtrip"
        );
        assert_eq!(
            generate(loaded, &[3, 11], 24),
            generate(original, &[3, 11], 24),
            "{tag}: greedy decode must be bit-identical after a roundtrip"
        );
    }
}

#[test]
fn corrupt_headers_are_rejected_not_trusted() {
    let config = LmConfig { kind: RnnKind::Gru, vocab: 50, hidden: 16, layers: 1 };
    let model = RnnLm::random(config, 9, PrecisionPolicy::quantized(2, 2));
    let path = temp_amqz("corrupt");
    amqz::save(&path, &model.to_packed().unwrap()).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Truncation, a flipped magic byte, and a bumped version must all fail
    // cleanly — never panic, never hand back a model.
    let cases: Vec<Vec<u8>> = vec![
        good[..good.len() / 2].to_vec(),
        {
            let mut b = good.clone();
            b[0] ^= 0xff;
            b
        },
        {
            let mut b = good.clone();
            b[4] = 0xee;
            b
        },
        good[..16].to_vec(),
    ];
    for (i, bytes) in cases.iter().enumerate() {
        std::fs::write(&path, bytes).unwrap();
        assert!(amqz::load_model(&path).is_err(), "corrupt case {i} must be rejected");
    }
    std::fs::remove_file(&path).ok();
}

/// The headline number: bringing a model up from `.amqz` is one bulk read
/// into an arena, no parse and no alternating-minimization requantize, so
/// it must be at least 5× faster than building the same model from
/// weights.
#[test]
fn cold_load_beats_requantize_by_5x() {
    let config = LmConfig { kind: RnnKind::Gru, vocab: 1500, hidden: 64, layers: 1 };
    let policy = PrecisionPolicy::quantized(2, 2);
    let built = RnnLm::random(config, 7, policy);
    let path = temp_amqz("coldload");
    amqz::save(&path, &built.to_packed().unwrap()).unwrap();

    let best_of_3 = |f: &dyn Fn() -> usize| -> f64 {
        (0..3)
            .map(|_| {
                let t = Instant::now();
                std::hint::black_box(f());
                t.elapsed().as_secs_f64() * 1e3
            })
            .fold(f64::INFINITY, f64::min)
    };
    let requantize_ms = best_of_3(&|| RnnLm::random(config, 7, policy).bytes());
    let load_ms = best_of_3(&|| amqz::load_model(&path).unwrap().bytes());
    std::fs::remove_file(&path).ok();

    assert!(
        load_ms * 5.0 <= requantize_ms,
        "cold load {load_ms:.2}ms vs requantize {requantize_ms:.2}ms: want >= 5x"
    );
}

#[test]
fn registry_hot_swaps_models_with_lru_evictions() {
    let config = LmConfig { kind: RnnKind::Gru, vocab: 80, hidden: 24, layers: 1 };
    let policy = PrecisionPolicy::quantized(2, 2);
    let names = ["alpha", "beta", "gamma"];

    // Publish three distinct models; keep the in-memory originals as the
    // bit-exact references.
    let mut originals: Vec<Arc<RnnLm>> = Vec::new();
    let mut paths = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let m = Arc::new(RnnLm::random(config, 100 + i as u64, policy));
        let path = temp_amqz(name);
        amqz::save(&path, &m.to_packed().unwrap()).unwrap();
        originals.push(m);
        paths.push(path);
    }

    // Room for two resident models, never three: cycling α→β→γ must evict
    // the least-recently-used lane on every acquire past the second.
    let budget = originals[0].bytes() * 5 / 2;
    let mut registry = ModelRegistry::new(budget);
    for (name, path) in names.iter().zip(&paths) {
        registry.register_path(name, path.clone()).unwrap();
    }
    registry.alias("a0", "alpha").unwrap();
    registry.set_default("alpha").unwrap();

    let server = InferenceServer::with_registry(
        registry,
        BatcherConfig {
            max_batch: 2,
            continuous: true,
            max_slots: 2,
            queue_depth: 16,
            exec: ExecConfig::serial(),
            ..Default::default()
        },
        Exec::serial(),
    );
    let (tx, rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(rx));

    let mut session = 0u64;
    for round in 0..3usize {
        for (i, name) in names.iter().enumerate() {
            session += 1;
            let prime = vec![(round * 3 + i + 1) % 80];
            let want = generate(originals[i].clone(), &prime, 12);
            // The last alpha request goes through the alias: it must hit
            // the same lane, not a second copy.
            let pick = if round == 2 && i == 0 { "a0" } else { name };
            let (rtx, rrx) = mpsc::channel();
            tx.send(Work::Gen(Request {
                session,
                max_new: 12,
                prime,
                model: Some(pick.to_string()),
                respond: Respond::Channel(rtx),
                enqueued: Instant::now(),
            }))
            .unwrap();
            match rrx.recv().unwrap() {
                Reply::Gen(resp) => assert_eq!(
                    resp.tokens, want,
                    "round {round}, model {name}: registry-served decode diverged"
                ),
                other => panic!("round {round}, model {name}: unexpected reply {other:?}"),
            }
        }
    }

    let (rtx, rrx) = mpsc::channel();
    tx.send(Work::Stats { text: false, respond: Respond::Channel(rtx) }).unwrap();
    let stats = match rrx.recv().unwrap() {
        Reply::Stats(s) => s,
        other => panic!("unexpected reply {other:?}"),
    };
    tx.send(Work::Shutdown).unwrap();
    batcher.join().unwrap();
    for p in &paths {
        std::fs::remove_file(p).ok();
    }

    assert!(stats.contains("\"models\":{"), "{stats}");
    for name in names {
        assert!(stats.contains(&format!("\"{name}\":{{")), "missing per-model stats: {stats}");
    }
    let evictions: u64 = stats
        .split("\"model_evictions\":")
        .nth(1)
        .and_then(|t| {
            t.chars().take_while(|c| c.is_ascii_digit()).collect::<String>().parse().ok()
        })
        .unwrap_or_else(|| panic!("missing model_evictions in {stats}"));
    assert!(
        evictions >= 3,
        "cycling 3 models under a 2-model budget must evict (got {evictions}): {stats}"
    );
    assert!(stats.contains("\"hits\":"), "{stats}");
}
