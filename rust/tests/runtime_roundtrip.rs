//! Integration: the AOT artifacts execute from Rust and agree with the
//! native inference engine (the golden cross-layer contract).
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent so
//! `cargo test` stays green on a fresh checkout.

use std::path::Path;

use amq::data::checkpoint::Checkpoint;
use amq::model::lm::{PrecisionPolicy, RnnLm};
use amq::runtime::{Arg, Engine, HostTensor, HostTokens};
use amq::train::trainer::{weights_from_checkpoint, Manifest};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("lstm_fp.manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn eval_artifact_matches_native_ppw() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir.join("lstm_fp.manifest.txt")).unwrap();
    let init = Checkpoint::load(&dir.join("lstm_fp_init.amqt")).unwrap();
    let config = manifest.lm_config();
    let weights = weights_from_checkpoint(&init, &config).unwrap();
    let native = RnnLm::from_weights(config, &weights, PrecisionPolicy::full());

    // One window of synthetic tokens, batch layout matching the artifact.
    let (b, t) = (manifest.batch, manifest.bptt);
    let mut rng = amq::util::Rng::new(42);
    let x: Vec<usize> = (0..b * t).map(|_| rng.below(manifest.vocab)).collect();
    let y: Vec<usize> = (0..b * t).map(|_| rng.below(manifest.vocab)).collect();

    // Native: per stream, fresh zero state, accumulate NLL of y given x.
    let mut native_nll = 0.0f64;
    for bi in 0..b {
        let mut state = native.zero_state();
        for ti in 0..t {
            let logits = native.step(x[bi * t + ti], &mut state);
            native_nll -= amq::model::math::log_softmax_at(&logits, y[bi * t + ti]) as f64;
        }
    }

    // Artifact: same computation through PJRT.
    let mut engine = Engine::cpu(dir).unwrap();
    engine.load("lstm_fp_eval").unwrap();
    let params: Vec<HostTensor> = manifest
        .params
        .iter()
        .map(|(name, shape)| {
            let t = init.get(name).unwrap();
            assert_eq!(&t.shape, shape);
            HostTensor::new(t.shape.clone(), t.data.clone())
        })
        .collect();
    let h0 = HostTensor::new(vec![b, manifest.hidden], vec![0.0; b * manifest.hidden]);
    let c0 = h0.clone();
    let xt = HostTokens::new(vec![b, t], x.iter().map(|&v| v as i32).collect());
    let yt = HostTokens::new(vec![b, t], y.iter().map(|&v| v as i32).collect());
    let mut args: Vec<Arg<'_>> = params.iter().map(Arg::F32).collect();
    args.push(Arg::F32(&h0));
    args.push(Arg::F32(&c0));
    args.push(Arg::I32(&xt));
    args.push(Arg::I32(&yt));
    let out = engine.execute("lstm_fp_eval", &args).unwrap();
    // outputs: h', c', sum_nll, count
    assert_eq!(out.len(), 4);
    let artifact_nll = out[2].data[0] as f64;
    let count = out[3].data[0] as f64;
    assert_eq!(count as usize, b * t);

    let rel = (artifact_nll - native_nll).abs() / native_nll.abs();
    assert!(
        rel < 1e-3,
        "cross-layer NLL mismatch: native {native_nll:.4} vs artifact {artifact_nll:.4}"
    );
}

#[test]
fn train_artifact_decreases_loss() {
    let Some(dir) = artifacts() else { return };
    let mut trainer = amq::train::LmTrainer::load(dir, "lstm_fp").unwrap();
    let spec = amq::data::DatasetSpec::ptb_like().scaled(64, 5);
    let corpus = amq::data::Corpus::generate(spec);
    let (l0, _) = trainer.train_epoch(&corpus.train, 10.0, Some(3)).unwrap();
    let (l1, _) = trainer.train_epoch(&corpus.train, 10.0, Some(3)).unwrap();
    let (l2, _) = trainer.train_epoch(&corpus.train, 10.0, Some(3)).unwrap();
    assert!(
        l2 < l0,
        "loss should decrease over repeated epochs: {l0:.4} → {l1:.4} → {l2:.4}"
    );
}

#[test]
fn quantized_train_artifact_runs() {
    let Some(dir) = artifacts() else { return };
    let mut trainer = amq::train::LmTrainer::load(dir, "lstm_w2a2").unwrap();
    let spec = amq::data::DatasetSpec::ptb_like().scaled(64, 5);
    let corpus = amq::data::Corpus::generate(spec);
    let (loss, steps) = trainer.train_epoch(&corpus.train, 5.0, Some(2)).unwrap();
    assert_eq!(steps, 2);
    assert!(loss.is_finite() && loss > 0.0);
    // Weight clip invariant from the training graph.
    for t in &trainer.params {
        assert!(t.data.iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }
}

#[test]
fn eval_after_one_step_changes() {
    let Some(dir) = artifacts() else { return };
    let mut trainer = amq::train::LmTrainer::load(dir, "gru_fp").unwrap();
    let spec = amq::data::DatasetSpec::ptb_like().scaled(64, 5);
    let corpus = amq::data::Corpus::generate(spec);
    let before = trainer.evaluate(&corpus.valid, Some(2)).unwrap();
    trainer.train_epoch(&corpus.train, 10.0, Some(3)).unwrap();
    let after = trainer.evaluate(&corpus.valid, Some(2)).unwrap();
    assert_ne!(before, after);
    assert!(after < before, "one epoch should lower val ppw: {before:.1} → {after:.1}");
}
