//! End-to-end serving integration: quantized model behind the TCP front
//! end, concurrent clients, session continuity, failure handling, and the
//! threaded-vs-serial stress parity of the execution engine.
//!
//! With `AMQ_EVENTLOOP=1` every test runs against the epoll/kqueue
//! event-loop front end with **continuous batching** instead of the
//! thread-per-connection front end with grouped batching — same wire
//! protocol, same expected bytes (CI runs both legs; the stress test's
//! bit-match then covers continuous-vs-serial over real TCP).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;

use amq::exec::ExecConfig;
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::server::batcher::{BatcherConfig, InferenceServer, Work};
use amq::server::tcp;

fn use_eventloop() -> bool {
    cfg!(unix) && std::env::var("AMQ_EVENTLOOP").map(|v| v == "1").unwrap_or(false)
}

struct TestServer {
    addr: std::net::SocketAddr,
    work: mpsc::Sender<Work>,
    batcher: std::thread::JoinHandle<()>,
    #[cfg(unix)]
    #[allow(dead_code)] // held so the loop threads outlive the test body
    evloop: Option<amq::server::eventloop::EventLoopServer>,
}

fn start_with(max_batch: usize, exec: ExecConfig) -> TestServer {
    let lm = RnnLm::random(
        LmConfig { kind: RnnKind::Lstm, vocab: 60, hidden: 24, layers: 1 },
        123,
        PrecisionPolicy::quantized(2, 2),
    );
    let server = InferenceServer::new(
        Arc::new(lm),
        BatcherConfig {
            max_batch,
            batch_wait: std::time::Duration::from_micros(300),
            max_sessions: 64,
            continuous: use_eventloop(),
            exec,
            ..Default::default()
        },
    );
    let (tx, rx) = mpsc::channel();
    let batcher = std::thread::spawn(move || server.run(rx));
    #[cfg(unix)]
    if use_eventloop() {
        let srv = amq::server::eventloop::serve(
            "127.0.0.1:0",
            tx.clone(),
            amq::server::eventloop::EventLoopConfig { loops: 2, ..Default::default() },
        )
        .expect("event-loop bind");
        return TestServer { addr: srv.addr, work: tx, batcher, evloop: Some(srv) };
    }
    let (atx, arx) = mpsc::channel();
    let tx2 = tx.clone();
    let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
    std::thread::spawn(move || {
        let _ = tcp::serve("127.0.0.1:0", tx2, shutdown, move |a| {
            let _ = atx.send(a);
        });
    });
    TestServer {
        addr: arx.recv().unwrap(),
        work: tx,
        batcher,
        #[cfg(unix)]
        evloop: None,
    }
}

fn start(max_batch: usize) -> TestServer {
    start_with(max_batch, ExecConfig::auto())
}

fn request(addr: std::net::SocketAddr, line: &str) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut r = BufReader::new(conn);
    let mut out = String::new();
    r.read_line(&mut out).unwrap();
    out.trim().to_string()
}

#[test]
fn concurrent_clients_all_served() {
    let s = start(8);
    let addr = s.addr;
    let handles: Vec<_> = (0..12)
        .map(|i| {
            std::thread::spawn(move || request(addr, &format!("GEN {i} 5 {},{}", i % 60, (i + 7) % 60)))
        })
        .collect();
    for h in handles {
        let resp = h.join().unwrap();
        assert!(resp.starts_with("OK GEN "), "{resp}");
        assert_eq!(resp.trim_start_matches("OK GEN ").split(',').count(), 5);
    }
    let stats = request(addr, "STATS");
    assert!(stats.starts_with("OK STATS {"), "STATS is one-line JSON: {stats}");
    assert!(stats.contains("\"requests\":12"), "{stats}");
    let text = request(addr, "STATS TEXT");
    assert!(text.contains("requests=12"), "{text}");
    let _ = s.work.send(Work::Shutdown);
}

#[test]
fn session_state_survives_across_connections() {
    let s = start(4);
    // Same session twice: server must keep its hidden state between calls.
    let a = request(s.addr, "GEN 77 4 3,4,5");
    let b = request(s.addr, "GEN 77 4 9");
    assert!(a.starts_with("OK GEN ") && b.starts_with("OK GEN "));
    // Fresh session with same prime as the second call can differ (state!).
    let c = request(s.addr, "GEN 78 4 9");
    assert!(c.starts_with("OK GEN "));
    let ended = request(s.addr, "END 77");
    assert_eq!(ended, "OK END");
    let again = request(s.addr, "END 77");
    assert!(again.contains("no such session"), "{again}");
    let _ = s.work.send(Work::Shutdown);
}

#[test]
fn malformed_requests_get_errors_not_disconnects() {
    let s = start(4);
    let mut conn = TcpStream::connect(s.addr).unwrap();
    conn.write_all(b"BOGUS\nGEN 1 0 1\nSCORE 5\nGEN 1 2 1\n").unwrap();
    let mut r = BufReader::new(conn.try_clone().unwrap());
    let mut lines = Vec::new();
    for _ in 0..4 {
        let mut l = String::new();
        r.read_line(&mut l).unwrap();
        lines.push(l.trim().to_string());
    }
    assert!(lines[0].starts_with("ERR "));
    assert!(lines[1].starts_with("ERR "));
    assert!(lines[2].starts_with("ERR "));
    assert!(lines[3].starts_with("OK GEN "), "recovers after errors: {lines:?}");
    let _ = s.work.send(Work::Shutdown);
}

#[test]
fn score_is_deterministic_and_finite() {
    let s = start(4);
    let a = request(s.addr, "SCORE 1,2,3,4,5,6");
    let b = request(s.addr, "SCORE 1,2,3,4,5,6");
    assert_eq!(a, b);
    let ppw: f64 = a.trim_start_matches("OK SCORE ").parse().unwrap();
    assert!(ppw.is_finite() && ppw > 1.0);
    let _ = s.work.send(Work::Shutdown);
}

/// Stress + parity: N concurrent TCP clients interleaving prime/generate/
/// continue/end against a *threaded, batching* server must observe exactly
/// the outputs of a `threads = 1, max_batch = 1` reference run — the
/// worker pool and the dynamic batcher are both invisible. Shutdown must
/// join the batcher thread (which drops the pool and joins its workers —
/// no leaked threads, no deadlock on drop).
#[test]
fn threaded_server_bitmatches_serial_reference_under_concurrent_stress() {
    const CLIENTS: usize = 8;
    // Each session issues: GEN (two-token prime), GEN (continuation), END.
    let script = |i: usize| {
        let (p1, p2, p3) = (i % 60, (i * 7 + 3) % 60, (i * 11 + 5) % 60);
        (
            format!("GEN {i} 6 {p1},{p2}"),
            format!("GEN {i} 4 {p3}"),
            format!("END {i}"),
        )
    };

    // Reference: strictly serial server (1 thread, batch of 1), sessions
    // run one after another.
    let reference: Vec<(String, String)> = {
        let s = start_with(1, ExecConfig::serial());
        let out = (0..CLIENTS)
            .map(|i| {
                let (g1, g2, end) = script(i);
                let a = request(s.addr, &g1);
                let b = request(s.addr, &g2);
                assert_eq!(request(s.addr, &end), "OK END");
                (a, b)
            })
            .collect();
        let _ = s.work.send(Work::Shutdown);
        s.batcher.join().expect("reference batcher joins");
        out
    };
    assert!(reference.iter().all(|(a, b)| a.starts_with("OK GEN ") && b.starts_with("OK GEN ")));

    // Threaded batching server, all sessions hammering concurrently.
    let s = start_with(4, ExecConfig::with_threads(3));
    let addr = s.addr;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            std::thread::spawn(move || {
                let (g1, g2, end) = script(i);
                let a = request(addr, &g1);
                let b = request(addr, &g2);
                assert_eq!(request(addr, &end), "OK END");
                (a, b)
            })
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        assert_eq!(
            got, reference[i],
            "session {i}: threaded+batched output diverged from serial reference"
        );
    }

    // Clean shutdown joins the batcher (and thereby the worker pool).
    let _ = s.work.send(Work::Shutdown);
    s.batcher.join().expect("batcher thread joins after shutdown");
}
