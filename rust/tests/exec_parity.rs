//! Exact-parity contract of the execution engine: threading must be
//! invisible — every row-sharded / pooled path bit-matches the serial path
//! with **no tolerance** (`==` on f32), for every (method, k_w, k_x, B,
//! threads) grid point, including shapes whose rows/cols are not multiples
//! of 64 and pools with more threads than rows (oversubscription).
//!
//! This is the property that lets the server turn on a worker pool without
//! changing a single client-visible token.

use amq::exec::{Exec, ExecConfig};
use amq::kernels::binary::PreparedGemm;
use amq::model::batch::{ActivationBatch, OutputBatch};
use amq::model::gru::GruCell;
use amq::model::linear::{LinearOp, Precision};
use amq::model::lm::{LmConfig, PrecisionPolicy, RnnKind, RnnLm};
use amq::model::lstm::{LstmCell, LstmState, LstmStateBatch};
use amq::quant::{Method, QuantizedBatch, RowQuantized};
use amq::util::Rng;

const THREAD_GRID: [usize; 4] = [1, 2, 3, 8];

fn engines() -> Vec<(usize, Exec)> {
    THREAD_GRID
        .iter()
        .map(|&t| (t, Exec::new(ExecConfig::with_threads(t))))
        .collect()
}

/// The full GEMM grid: every method × bit-width pairing × batch × thread
/// count, on shapes with tail words (cols % 64 ≠ 0) and few rows (rows <
/// max threads ⇒ oversubscription).
#[test]
fn gemm_exec_bitmatches_serial_across_full_grid() {
    let mut rng = Rng::new(9001);
    let engines = engines();
    let methods = [Method::Alternating { t: 2 }, Method::Greedy, Method::Uniform];
    // (rows, cols): 3 < 8 threads oversubscribes; 147/70 exercise tail
    // words; 64 is the exact word boundary.
    let shapes = [(3usize, 70usize), (13, 147), (16, 64)];
    for method in methods {
        for (k_w, k_x) in [(1usize, 1usize), (2, 2), (2, 3), (3, 2), (4, 4)] {
            for &(m, n) in &shapes {
                let w = rng.normal_vec(m * n, 0.3);
                let prep = PreparedGemm::new(&RowQuantized::quantize(&w, m, n, k_w, method));
                for batch in [1usize, 3, 16] {
                    let x = rng.normal_vec(batch * n, 1.0);
                    let xq = QuantizedBatch::quantize(&x, batch, n, k_x);
                    let mut serial = vec![0.0f32; batch * m];
                    prep.gemm(&xq, &mut serial);
                    for (t, exec) in &engines {
                        let mut y = vec![0.0f32; batch * m];
                        prep.gemm_exec(&xq, &mut y, exec);
                        assert_eq!(
                            y, serial,
                            "{method:?} k_w={k_w} k_x={k_x} m={m} n={n} B={batch} threads={t}"
                        );
                    }
                }
            }
        }
    }
}

/// Row-sharded weight-matrix quantization is bit-identical to serial for
/// every method and thread count (alphas and packed planes both).
#[test]
fn matrix_quantize_exec_bitmatches_serial() {
    let mut rng = Rng::new(9002);
    let engines = engines();
    for method in [
        Method::Alternating { t: 2 },
        Method::Greedy,
        Method::Refined,
        Method::Uniform,
        Method::Balanced,
        Method::Ternary,
    ] {
        for (rows, cols) in [(1usize, 1usize), (5, 70), (13, 147)] {
            let w = rng.normal_vec(rows * cols, 0.4);
            for k in 1..=3 {
                let serial = RowQuantized::quantize(&w, rows, cols, k, method);
                for (t, exec) in &engines {
                    let par = RowQuantized::quantize_exec(&w, rows, cols, k, method, exec);
                    assert_eq!(par.alphas, serial.alphas, "{method:?} k={k} threads={t}");
                    assert_eq!(par.planes, serial.planes, "{method:?} k={k} threads={t}");
                }
            }
        }
    }
}

/// Row-sharded online activation quantization is bit-identical to serial.
#[test]
fn batch_quantize_exec_bitmatches_serial() {
    let mut rng = Rng::new(9003);
    let engines = engines();
    for (batch, n) in [(1usize, 1usize), (3, 70), (16, 130)] {
        let x = rng.normal_vec(batch * n, 1.0);
        for k in 1..=3 {
            let serial = QuantizedBatch::quantize(&x, batch, n, k);
            for (t, exec) in &engines {
                let par = QuantizedBatch::quantize_exec(&x, batch, n, k, exec);
                assert_eq!(par.alphas, serial.alphas, "B={batch} n={n} k={k} threads={t}");
                assert_eq!(par.data, serial.data, "B={batch} n={n} k={k} threads={t}");
            }
        }
    }
}

/// The dense backend's column sharding is bit-exact too (FP layers inside a
/// mixed-precision model must not drift under threading).
#[test]
fn dense_forward_exec_bitmatches_serial() {
    let mut rng = Rng::new(9004);
    let engines = engines();
    let (m, n, batch) = (17, 70, 5);
    let layer = amq::model::Linear::new(rng.normal_vec(m * n, 0.3), m, n, Precision::Full);
    let x = rng.normal_vec(batch * n, 1.0);
    let xb = ActivationBatch::from_flat(x, batch, n);
    let mut serial = OutputBatch::zeros(batch, m);
    layer.forward(&xb, &mut serial);
    for (t, exec) in &engines {
        let mut y = OutputBatch::zeros(batch, m);
        layer.forward_exec(&xb, &mut y, exec);
        assert_eq!(y.data(), serial.data(), "threads={t}");
    }
}

/// LSTM gate products as pooled tasks + row-sharded GEMMs: bit-exact per
/// column for every thread count.
#[test]
fn lstm_step_batch_exec_bitmatches_serial() {
    let mut rng = Rng::new(9005);
    let engines = engines();
    for precision in [Precision::Full, Precision::Quantized { k_w: 2, k_a: 2 }] {
        let cell = LstmCell::init(10, 12, 0.4, &mut rng, precision);
        for batch in [1usize, 3, 8] {
            let singles: Vec<LstmState> = (0..batch)
                .map(|_| LstmState { h: rng.normal_vec(12, 0.5), c: rng.normal_vec(12, 0.5) })
                .collect();
            let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(10, 1.0)).collect();
            let refs: Vec<&LstmState> = singles.iter().collect();
            let sb = LstmStateBatch::from_states(&refs);
            let xrows: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let xb = ActivationBatch::from_rows(&xrows);
            let serial = cell.step_batch(&xb, &sb);
            for (t, exec) in &engines {
                let next = cell.step_batch_exec(&xb, &sb, exec);
                assert_eq!(next, serial, "{precision:?} batch={batch} threads={t}");
            }
        }
    }
}

/// GRU, same contract.
#[test]
fn gru_step_batch_exec_bitmatches_serial() {
    let mut rng = Rng::new(9006);
    let engines = engines();
    for precision in [Precision::Full, Precision::Quantized { k_w: 2, k_a: 2 }] {
        let cell = GruCell::init(9, 14, 0.4, &mut rng, precision);
        for batch in [1usize, 4] {
            let hs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(14, 0.5)).collect();
            let xs: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(9, 1.0)).collect();
            let hrows: Vec<&[f32]> = hs.iter().map(|v| v.as_slice()).collect();
            let xrows: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let hb = ActivationBatch::from_rows(&hrows);
            let xb = ActivationBatch::from_rows(&xrows);
            let serial = cell.step_batch(&xb, &hb);
            for (t, exec) in &engines {
                let next = cell.step_batch_exec(&xb, &hb, exec);
                assert_eq!(next, serial, "{precision:?} batch={batch} threads={t}");
            }
        }
    }
}

/// Whole-model contract: a multi-round batched generation (embedding incl.
/// prequant rows, cells, softmax) is bit-exact for every thread count and
/// both cell kinds — and model *construction* on a pool yields the same
/// model as serial construction.
#[test]
fn lm_step_batch_exec_bitmatches_serial_over_rounds() {
    let engines = engines();
    for kind in [RnnKind::Lstm, RnnKind::Gru] {
        for policy in [PrecisionPolicy::full(), PrecisionPolicy::quantized(2, 2)] {
            let config = LmConfig { kind, vocab: 50, hidden: 32, layers: 1 };
            let lm = RnnLm::random(config, 11, policy);
            for (t, exec) in &engines {
                // Parallel construction must give the identical model.
                let lm_par = RnnLm::random_exec(config, 11, policy, exec);
                let batch = 5;
                let mut serial_state = lm.zero_state_batch(batch);
                let mut exec_state = lm.zero_state_batch(batch);
                let mut par_state = lm_par.zero_state_batch(batch);
                for round in 0..3 {
                    let tokens: Vec<usize> =
                        (0..batch).map(|b| (7 * b + 13 * round + 1) % 50).collect();
                    let serial = lm.step_batch(&tokens, &mut serial_state);
                    let threaded = lm.step_batch_exec(&tokens, &mut exec_state, exec);
                    let built_par = lm_par.step_batch_exec(&tokens, &mut par_state, exec);
                    assert_eq!(
                        threaded.data(),
                        serial.data(),
                        "{kind:?} round={round} threads={t}"
                    );
                    assert_eq!(
                        built_par.data(),
                        serial.data(),
                        "parallel-built model {kind:?} round={round} threads={t}"
                    );
                    assert_eq!(exec_state, serial_state, "{kind:?} round={round} threads={t}");
                }
            }
        }
    }
}

/// Extreme oversubscription: far more threads than rows, batch 1, single
/// row — the degenerate corners all still bit-match.
#[test]
fn oversubscription_corners_bitmatch() {
    let mut rng = Rng::new(9007);
    let exec = Exec::new(ExecConfig::with_threads(8));
    for (m, n) in [(1usize, 1usize), (1, 64), (2, 65), (7, 64)] {
        let w = rng.normal_vec(m * n, 0.3);
        let prep = PreparedGemm::new(&RowQuantized::quantize(
            &w,
            m,
            n,
            2,
            Method::Alternating { t: 2 },
        ));
        let x = rng.normal_vec(n, 1.0);
        let xq = QuantizedBatch::quantize(&x, 1, n, 2);
        let mut serial = vec![0.0f32; m];
        let mut threaded = vec![0.0f32; m];
        prep.gemm(&xq, &mut serial);
        prep.gemm_exec(&xq, &mut threaded, &exec);
        assert_eq!(threaded, serial, "m={m} n={n}");
    }
}
