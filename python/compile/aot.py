"""AOT lowering: JAX -> HLO *text* artifacts for the Rust PJRT runtime.

Emits, per tag `{lstm,gru}_{fp,w2a2,w2a3,w3a3}`:
    artifacts/<tag>_train.hlo.txt     one clipped-SGD STE step
    artifacts/<tag>_eval.hlo.txt      forward NLL
    artifacts/<tag>.manifest.txt      geometry + ordered parameter list
    artifacts/<tag>_init.amqt         initial parameters

HLO **text** (not ``lowered.compile()``/serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids that the crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Flat argument order (the contract with rust/src/train/trainer.rs):
    params (PARAM_ORDER) | state (h0[, c0]) | x | y | [lr]
Outputs (return_tuple=True):
    train: params' | state' | mean_nll        eval: state' | sum_nll | count

Usage: python -m compile.aot [--out DIR] [--tags a,b] [--vocab N] ...
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tensorio

# Shared reduced geometry (DESIGN.md §4: one artifact set serves the three
# vocab-scaled corpora).
DEFAULTS = dict(vocab=2000, hidden=200, batch=20, bptt=30)

SETTINGS = {
    "fp": (0, 0),
    "w2a2": (2, 2),
    "w2a3": (2, 3),
    "w3a3": (3, 3),
}


def all_tags():
    return [f"{kind}_{s}" for kind in ("lstm", "gru") for s in SETTINGS]


def spec_for_tag(tag, geo):
    kind, setting = tag.split("_")
    w_bits, a_bits = SETTINGS[setting]
    return M.ModelSpec(
        kind=kind, vocab=geo["vocab"], hidden=geo["hidden"], w_bits=w_bits, a_bits=a_bits
    )


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def flat_train_fn(spec):
    nstate = 2 if spec.kind == "lstm" else 1

    def fn(*args):
        np_ = len(M.PARAM_ORDER)
        params = dict(zip(M.PARAM_ORDER, args[:np_]))
        state = args[np_ : np_ + nstate]
        x, y, lr = args[np_ + nstate :]
        new, carry, loss = M.make_train_step(spec)(params, state, x, y, lr)
        return tuple(new[k] for k in M.PARAM_ORDER) + tuple(carry) + (loss,)

    return fn


def flat_eval_fn(spec):
    nstate = 2 if spec.kind == "lstm" else 1

    def fn(*args):
        np_ = len(M.PARAM_ORDER)
        params = dict(zip(M.PARAM_ORDER, args[:np_]))
        state = args[np_ : np_ + nstate]
        x, y = args[np_ + nstate :]
        carry, total, count = M.make_eval_step(spec)(params, state, x, y)
        return tuple(carry) + (total, count)

    return fn


def example_args(spec, geo, with_lr):
    shapes = M.param_shapes(spec)
    args = [jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in M.PARAM_ORDER]
    nstate = 2 if spec.kind == "lstm" else 1
    for _ in range(nstate):
        args.append(jax.ShapeDtypeStruct((geo["batch"], geo["hidden"]), jnp.float32))
    args.append(jax.ShapeDtypeStruct((geo["batch"], geo["bptt"]), jnp.int32))
    args.append(jax.ShapeDtypeStruct((geo["batch"], geo["bptt"]), jnp.int32))
    if with_lr:
        args.append(jax.ShapeDtypeStruct((), jnp.float32))
    return args


def write_manifest(path, spec, geo):
    shapes = M.param_shapes(spec)
    with open(path, "w") as f:
        f.write(f"kind {spec.kind}\n")
        f.write(f"vocab {geo['vocab']}\nhidden {geo['hidden']}\n")
        f.write(f"batch {geo['batch']}\nbptt {geo['bptt']}\n")
        for name in M.PARAM_ORDER:
            dims = ",".join(str(d) for d in shapes[name])
            f.write(f"param {name} {dims}\n")


def build_tag(tag, geo, out_dir, seed=1):
    spec = spec_for_tag(tag, geo)
    train = flat_train_fn(spec)
    ev = flat_eval_fn(spec)

    lowered_train = jax.jit(train).lower(*example_args(spec, geo, with_lr=True))
    with open(os.path.join(out_dir, f"{tag}_train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_train))

    lowered_eval = jax.jit(ev).lower(*example_args(spec, geo, with_lr=False))
    with open(os.path.join(out_dir, f"{tag}_eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_eval))

    write_manifest(os.path.join(out_dir, f"{tag}.manifest.txt"), spec, geo)

    params = M.init_params(spec, seed=seed)
    tensorio.save(
        os.path.join(out_dir, f"{tag}_init.amqt"),
        {k: np.asarray(v) for k, v in params.items()},
    )
    print(f"  wrote {tag} (train+eval+manifest+init)")


def build_quant_artifacts(out_dir, rows=64, cols=128, bits=(2, 3)):
    """Standalone quantization artifacts (w -> dequantized w-hat) for the
    cross-layer golden test: Rust quantizes the same matrix natively and
    compares reconstruction error against the Pallas kernel's output."""
    from .kernels import alt_quant

    for k in bits:
        fn = lambda w, k=k: (alt_quant.quantize_rows_dequant(w, k, 2),)
        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((rows, cols), jnp.float32))
        with open(os.path.join(out_dir, f"quant_k{k}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"  wrote quant_k{k} ({rows}x{cols})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tags", default=",".join(all_tags()))
    for k, v in DEFAULTS.items():
        ap.add_argument(f"--{k}", type=int, default=v)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()
    geo = {k: getattr(args, k) for k in DEFAULTS}
    os.makedirs(args.out, exist_ok=True)
    tags = [t for t in args.tags.split(",") if t]
    print(f"AOT lowering {len(tags)} tags to {args.out} (geometry {geo})")
    for tag in tags:
        if tag not in all_tags():
            print(f"  unknown tag {tag}", file=sys.stderr)
            return 2
        build_tag(tag, geo, args.out, seed=args.seed)
    build_quant_artifacts(args.out)
    print("done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
