"""Layer-2: quantized LSTM / GRU language models in JAX.

The forward/backward graph implements the paper's bi-level training (Eq. 7)
with the straight-through estimator: full-precision master weights are
re-quantized every step by the Layer-1 Pallas kernel (``kernels.alt_quant``),
activations (`h_t`) are quantized online inside the scan, gradients pass
through both quantizers unchanged, weights are clipped to [-1, 1] after the
SGD update (the paper's outlier control), and gradients are clipped to
global norm 0.25.

Weight layouts match the Rust inference engine exactly
(`rust/src/model/{lstm,gru}.rs`): gate rows [i, f, o, g] (LSTM) / [r, z, n]
(GRU); `wx, wh: (gates*H, H)`; row-major.

NOTE dropout: the paper applies dropout 0.5. The AOT artifacts are
deterministic (no RNG inputs), so dropout is omitted; at the reduced
step budgets used on this testbed its regularization effect is immaterial.
Documented in DESIGN.md §4.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import alt_quant


class ModelSpec(NamedTuple):
    kind: str  # "lstm" | "gru"
    vocab: int
    hidden: int
    # 0 = full precision.
    w_bits: int = 0
    a_bits: int = 0

    @property
    def gates(self):
        return 4 if self.kind == "lstm" else 3

    @property
    def quantized(self):
        return self.w_bits > 0


PARAM_ORDER = ["embedding", "wx", "wh", "bias", "softmax_w", "softmax_b"]


def param_shapes(spec: ModelSpec):
    g, v, h = spec.gates, spec.vocab, spec.hidden
    return {
        "embedding": (v, h),
        "wx": (g * h, h),
        "wh": (g * h, h),
        "bias": (g * h,),
        "softmax_w": (v, h),
        "softmax_b": (v,),
    }


def init_params(spec: ModelSpec, seed: int = 0):
    """U(-0.1, 0.1) init, the standard LM recipe (§5)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_shapes(spec).items():
        key, sub = jax.random.split(key)
        if name.startswith("bias") or name == "softmax_b":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = jax.random.uniform(sub, shape, jnp.float32, -0.1, 0.1)
    return params


def _maybe_quantize_weights(params, spec: ModelSpec):
    """STE row-wise quantization of every weight matrix (not biases)."""
    if not spec.quantized:
        return params
    q = dict(params)
    for name in ["embedding", "wx", "wh", "softmax_w"]:
        q[name] = alt_quant.ste(params[name], spec.w_bits)
    return q


def _maybe_quantize_h(h, spec: ModelSpec):
    """Online activation quantization of the hidden state (per sample)."""
    if not spec.quantized or spec.a_bits == 0:
        return h
    return alt_quant.ste(h, spec.a_bits)


def _cell_step(spec: ModelSpec, qp, carry, x_t):
    """One recurrent step over a batch. x_t: (B, H) embedded input."""
    h = spec.hidden
    if spec.kind == "lstm":
        hp, cp = carry
        pre = x_t @ qp["wx"].T + hp @ qp["wh"].T + qp["bias"]  # (B, 4H)
        i = jax.nn.sigmoid(pre[:, 0:h])
        f = jax.nn.sigmoid(pre[:, h : 2 * h])
        o = jax.nn.sigmoid(pre[:, 2 * h : 3 * h])
        g = jnp.tanh(pre[:, 3 * h : 4 * h])
        c = f * cp + i * g
        hn = o * jnp.tanh(c)
        hn = _maybe_quantize_h(hn, spec)
        return (hn, c), hn
    else:
        (hp,) = carry
        gx = x_t @ qp["wx"].T  # (B, 3H)
        gh = hp @ qp["wh"].T
        b = qp["bias"]
        r = jax.nn.sigmoid(gx[:, 0:h] + gh[:, 0:h] + b[0:h])
        z = jax.nn.sigmoid(gx[:, h : 2 * h] + gh[:, h : 2 * h] + b[h : 2 * h])
        n = jnp.tanh(gx[:, 2 * h : 3 * h] + r * gh[:, 2 * h : 3 * h] + b[2 * h : 3 * h])
        hn = (1.0 - z) * n + z * hp
        hn = _maybe_quantize_h(hn, spec)
        return (hn,), hn


def forward(spec: ModelSpec, params, state, x):
    """Run the LM over a window.

    state: (h0,) or (h0, c0) each (B, H); x: (B, T) int32 tokens.
    Returns (new_state, logits (T, B, V)).
    """
    qp = _maybe_quantize_weights(params, spec)
    emb = jnp.take(qp["embedding"], x, axis=0)  # (B, T, H)
    xs = jnp.swapaxes(emb, 0, 1)  # (T, B, H)
    carry, hs = jax.lax.scan(functools.partial(_cell_step, spec, qp), tuple(state), xs)
    logits = hs @ qp["softmax_w"].T + qp["softmax_b"]  # (T, B, V)
    return carry, logits


def _nll(logits, y):
    """Sum negative log-likelihood. logits (T, B, V); y (B, T)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    yt = jnp.swapaxes(y, 0, 1)  # (T, B)
    picked = jnp.take_along_axis(logp, yt[:, :, None], axis=-1)[..., 0]
    return -jnp.sum(picked)


def loss_fn(spec: ModelSpec, params, state, x, y):
    carry, logits = forward(spec, params, state, x)
    n = jnp.asarray(x.shape[0] * x.shape[1], jnp.float32)
    return _nll(logits, y) / n, carry


def clip_global_norm(grads, clip):
    norm = jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def make_train_step(spec: ModelSpec, clip=0.25):
    """(params..., state..., x, y, lr) -> (params'..., state', mean_nll).

    Flat-argument signature for AOT lowering (see aot.py for the order).
    """

    def step(params, state, x, y, lr):
        (loss, carry), grads = jax.value_and_grad(
            lambda p: loss_fn(spec, p, state, x, y), has_aux=True
        )(params)
        grads = clip_global_norm(grads, clip)
        new = {k: params[k] - lr * grads[k] for k in params}
        # Weight clipping to [-1, 1] (§4 Training).
        new = {k: jnp.clip(v, -1.0, 1.0) for k, v in new.items()}
        # Detach the carried state (truncated BPTT across windows).
        carry = tuple(jax.lax.stop_gradient(c) for c in carry)
        return new, carry, loss

    return step


def make_eval_step(spec: ModelSpec):
    """(params..., state..., x, y) -> (state', sum_nll, count)."""

    def step(params, state, x, y):
        carry, logits = forward(spec, params, state, x)
        total = _nll(logits, y)
        count = jnp.asarray(x.shape[0] * x.shape[1], jnp.float32)
        return carry, total, count

    return step
