"""Layer-1 Pallas kernel: quantized matrix product by per-tile
reconstruction, the MXU-side analogue of the paper's Fig. 3 layout.

On a real TPU the multi-bit product is evaluated as k_w * k_h rank-1-scaled
binary contractions; the MXU has no XNOR/popcount datapath, so the efficient
mapping is: keep the packed planes in VMEM, reconstruct a (BLOCK_R, BLOCK_N)
weight tile as sum_i alpha_i * b_i (vector ops on the VPU), then feed the
reconstructed tile to the MXU `dot`. HBM traffic stays at the packed (k-bit)
footprint — the same bandwidth saving the CPU kernel gets — while the MXU
runs dense. This kernel expresses that schedule with BlockSpecs;
``interpret=True`` for CPU-PJRT execution (see alt_quant.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(alphas_ref, planes_ref, x_ref, o_ref, *, k):
    # alphas (BR, k), planes (BR, k, n), x (n, BC) -> o (BR, BC)
    alphas = alphas_ref[...]
    planes = planes_ref[...]
    x = x_ref[...]
    # VPU: reconstruct the weight tile from its k binary planes.
    w_tile = sum(alphas[:, i][:, None] * planes[:, i, :] for i in range(k))
    # MXU: dense tile matmul.
    o_ref[...] = jnp.dot(w_tile, x)


@functools.partial(jax.jit, static_argnums=(3,))
def quantized_matmul(alphas, planes, x, block_r=128):
    """y = (sum_i alpha_i b_i) @ x from the quantized representation.

    alphas: (rows, k), planes: (rows, k, n), x: (n, m) -> (rows, m).
    """
    rows, k = alphas.shape
    n, m = x.shape
    block_r = min(block_r, rows)
    padded = ((rows + block_r - 1) // block_r) * block_r
    ap = jnp.pad(alphas, ((0, padded - rows), (0, 0)))
    pp = jnp.pad(planes, ((0, padded - rows), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((padded, m), x.dtype),
        grid=(padded // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, m), lambda i: (i, 0)),
        interpret=True,
    )(ap, pp, x)
    return out[:rows]
