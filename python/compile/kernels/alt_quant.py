"""Layer-1 Pallas kernel: row-wise alternating multi-bit quantization
(Algorithms 1 + 2 of the paper) with STE-ready dequantized output.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CPU kernel walks a
binary search tree per scalar; on a TPU that control flow becomes
data-parallel mask arithmetic. One program instance owns a VMEM-resident
block of rows; greedy init, the k x k least-squares refit (unrolled Gaussian
elimination - k is a compile-time constant <= 4), and the optimal code
assignment (argmin over the 2^k composite codes == the BST's answer, proven
in tests against ``ref.bst_assign``) are all dense vector ops over the block.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel into plain HLO that the
Rust runtime runs. Real-TPU execution would keep the same BlockSpecs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default rows-per-program. 64 rows x 1024 cols x 4B x (k+2 live tensors)
# stays well under the ~16 MB VMEM budget of a TPU core.
DEFAULT_BLOCK = 64


def _solve_gauss(g, c, k):
    """Unrolled Gaussian elimination (no pivoting; ridge added by caller)
    over per-row k x k systems. g: list[list[(rows,)]], c: list[(rows,)]."""
    g = [[g[i][j] for j in range(k)] for i in range(k)]
    c = list(c)
    for col in range(k):
        for row in range(col + 1, k):
            f = g[row][col] / g[col][col]
            for j in range(col, k):
                g[row][j] = g[row][j] - f * g[col][j]
            c[row] = c[row] - f * c[col]
    alphas = [None] * k
    for row in reversed(range(k)):
        s = c[row]
        for j in range(row + 1, k):
            s = s - g[row][j] * alphas[j]
        alphas[row] = s / g[row][row]
    return alphas


def _alt_quant_block(w, k, cycles):
    """Alternating quantization of a (rows, n) block; returns dequantized
    (rows, n). Pure vector ops — runs inside the Pallas kernel."""
    n = w.shape[1]
    # Greedy init (Eq. 4), k static.
    planes = []
    alphas = []
    r = w
    for _ in range(k):
        a = jnp.mean(jnp.abs(r), axis=1)  # (rows,)
        b = jnp.where(r >= 0, 1.0, -1.0)  # (rows, n)
        r = r - a[:, None] * b
        alphas.append(a)
        planes.append(b)

    for _ in range(cycles):
        # (a) least-squares refit (Eq. 5) with ridge for dependent planes.
        g = [
            [
                jnp.sum(planes[i] * planes[j], axis=1)
                + (1e-6 * n if i == j else 0.0)
                for j in range(k)
            ]
            for i in range(k)
        ]
        c = [jnp.sum(planes[i] * w, axis=1) for i in range(k)]
        alphas = _solve_gauss(g, c, k)
        # (b) optimal code re-assignment (Algorithm 1 as argmin over all
        # 2^k codes — identical answer, data-parallel form).
        m = 1 << k
        # values[:, p] = sum_i sign(p, i) * alpha_i
        signs = (((jnp.arange(m)[:, None] >> jnp.arange(k)[None, :]) & 1) * 2 - 1).astype(
            w.dtype
        )  # (m, k)
        values = sum(signs[None, :, i] * alphas[i][:, None] for i in range(k))  # (rows, m)
        dist = jnp.abs(w[:, :, None] - values[:, None, :])  # (rows, n, m)
        idx = jnp.argmin(dist, axis=2)  # (rows, n)
        planes = [(((idx >> i) & 1) * 2 - 1).astype(w.dtype) for i in range(k)]

    out = sum(alphas[i][:, None] * planes[i] for i in range(k))
    return out


def _kernel(w_ref, o_ref, *, k, cycles):
    o_ref[...] = _alt_quant_block(w_ref[...], k, cycles)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def quantize_rows_dequant(w, k, cycles=2, block=DEFAULT_BLOCK):
    """Row-wise alternating quantize + reconstruct of a (rows, n) matrix via
    the Pallas kernel. Pads rows to a block multiple (zero rows quantize to
    zero and are sliced off)."""
    rows, n = w.shape
    block = min(block, rows)
    padded = ((rows + block - 1) // block) * block
    wp = jnp.pad(w, ((0, padded - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, k=k, cycles=cycles),
        out_shape=jax.ShapeDtypeStruct((padded, n), w.dtype),
        grid=(padded // block,),
        in_specs=[pl.BlockSpec((block, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        interpret=True,
    )(wp)
    return out[:rows]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def ste(w, k, cycles=2, block=DEFAULT_BLOCK):
    """Straight-through estimator (Eq. 7): forward = quantized value,
    backward = identity on w. A custom VJP (not ``stop_gradient``) because
    interpret-mode ``pallas_call`` defines no JVP rule to linearize through.
    """
    return quantize_rows_dequant(w, k, cycles, block)


def _ste_fwd(w, k, cycles, block):
    return ste(w, k, cycles, block), None


def _ste_bwd(k, cycles, block, _res, g):
    return (g,)


ste.defvjp(_ste_fwd, _ste_bwd)
