"""Pure-jnp reference implementation (correctness oracle) of the paper's
quantization algorithms.

Everything here is straight-line jnp so it can be checked against the Pallas
kernels at build time (pytest) and lowered into the L2 graphs when the
kernels are disabled. Layouts mirror the Rust side: matrices are quantized
row-by-row; planes use +1/-1 values.

The BST of Algorithm 1 appears in two equivalent data-parallel forms:
  * ``bst_assign``     — searchsorted against the midpoints of the sorted
                         code vector (the literal Algorithm 1, k comparisons)
  * ``argmin_assign``  — brute-force argmin over all 2^k codes (the
                         TPU-idiomatic masked form used inside the kernel)
``test_kernels.py`` proves they coincide.
"""

import functools

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Vector-level primitives, vmapped over rows.
# ---------------------------------------------------------------------------


def greedy_init(w, k):
    """Eq. 4: residue-greedy initialization.

    w: (n,) -> alphas (k,), planes (k, n) in {-1, +1}.
    """

    def step(r, _):
        alpha = jnp.mean(jnp.abs(r))
        b = jnp.where(r >= 0, 1.0, -1.0)
        return r - alpha * b, (alpha, b)

    _, (alphas, planes) = jax.lax.scan(step, w, None, length=k)
    return alphas, planes


def lsq_refit(w, planes, ridge=1e-6):
    """Eq. 5: alphas = (B^T B)^{-1} B^T w, with a tiny ridge for dependent
    planes. planes: (k, n)."""
    k, n = planes.shape
    g = planes @ planes.T + ridge * n * jnp.eye(k, dtype=w.dtype)
    c = planes @ w
    return jnp.linalg.solve(g, c)


def all_codes(alphas):
    """All 2^k composite codes: values (2^k,), sign patterns (2^k, k)."""
    k = alphas.shape[0]
    patterns = ((jnp.arange(2**k)[:, None] >> jnp.arange(k)[None, :]) & 1) * 2.0 - 1.0
    values = patterns @ alphas
    return values, patterns


def argmin_assign(w, alphas):
    """Optimal code assignment by brute-force argmin over the 2^k codes
    (identical to the BST by optimality). Returns planes (k, n)."""
    values, patterns = all_codes(alphas)
    idx = jnp.argmin(jnp.abs(w[None, :] - values[:, None]), axis=0)  # (n,)
    return patterns[idx].T  # (k, n)


def bst_assign(w, alphas):
    """Algorithm 1 literally: sort the codes, binary-search each entry
    against the midpoints of adjacent codes (k comparisons/entry)."""
    values, patterns = all_codes(alphas)
    order = jnp.argsort(values)
    values = values[order]
    patterns = patterns[order]
    mids = 0.5 * (values[1:] + values[:-1])
    idx = jnp.searchsorted(mids, w, side="right")
    return patterns[idx].T


def alternating_quantize(w, k, cycles=2):
    """Algorithm 2: greedy init, then `cycles` x (refit alphas; reassign
    codes). Returns (alphas (k,), planes (k, n))."""
    alphas, planes = greedy_init(w, k)
    for _ in range(cycles):  # static unroll: cycles is a compile-time const
        alphas = lsq_refit(w, planes)
        planes = argmin_assign(w, alphas)
    return alphas, planes


def dequantize(alphas, planes):
    return alphas @ planes


# Row-wise (matrix) forms ----------------------------------------------------


@functools.partial(jax.jit, static_argnums=(1, 2))
def quantize_rows(w, k, cycles=2):
    """Row-by-row alternating quantization of a (rows, n) matrix.
    Returns (alphas (rows, k), planes (rows, k, n))."""
    return jax.vmap(lambda row: alternating_quantize(row, k, cycles))(w)


@functools.partial(jax.jit, static_argnums=(1, 2))
def quantize_rows_dequant(w, k, cycles=2):
    """Row-wise quantize + reconstruct: the STE forward value."""
    alphas, planes = quantize_rows(w, k, cycles)
    return jnp.einsum("rk,rkn->rn", alphas, planes)


def relative_mse(w, w_hat):
    return jnp.sum((w - w_hat) ** 2) / jnp.sum(w**2)


def quantized_matmul(alphas, planes, x):
    """y = (sum_i alpha_i b_i) @ x computed from the quantized representation
    (the reconstruction contraction the inference kernel evaluates with
    XNOR/popcount). alphas (r,k), planes (r,k,n), x (n,) or (n,m)."""
    return jnp.einsum("rk,rkn->rn", alphas, planes) @ x
