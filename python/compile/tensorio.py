"""Named-tensor checkpoint IO — the Python half of the AMQT format shared
with ``rust/src/data/checkpoint.rs``.

Layout (little-endian):
    magic "AMQT" | u32 version | u32 tensor_count
    per tensor: u32 name_len | name | u32 ndim | u64 dims... | f32 data...
Tensors are written in sorted-name order (matching the Rust BTreeMap).
"""

import struct

import numpy as np

MAGIC = b"AMQT"
VERSION = 1


def save(path, tensors):
    """tensors: dict[str, np.ndarray] (float32)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load(path):
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError("bad AMQT magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"unsupported version {version}")
        out = {}
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = tuple(struct.unpack("<Q", f.read(8))[0] for _ in range(ndim))
            numel = int(np.prod(shape)) if shape else 1
            data = np.frombuffer(f.read(numel * 4), dtype="<f4")
            out[name] = data.reshape(shape).copy()
        return out
