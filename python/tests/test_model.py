"""Layer-2 checks: model shapes, training-step semantics, quantized variants,
and the AOT manifest/init contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import tensorio
from compile.aot import SETTINGS, all_tags, example_args, spec_for_tag


def tiny_spec(kind="lstm", w_bits=0, a_bits=0):
    return M.ModelSpec(kind=kind, vocab=50, hidden=16, w_bits=w_bits, a_bits=a_bits)


def zero_state(spec, batch):
    n = 2 if spec.kind == "lstm" else 1
    return tuple(jnp.zeros((batch, spec.hidden), jnp.float32) for _ in range(n))


def toy_batch(spec, batch=4, bptt=6, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, spec.vocab, size=(batch, bptt)), jnp.int32)
    y = jnp.asarray(rng.integers(0, spec.vocab, size=(batch, bptt)), jnp.int32)
    return x, y


@pytest.mark.parametrize("kind", ["lstm", "gru"])
def test_forward_shapes(kind):
    spec = tiny_spec(kind)
    params = M.init_params(spec)
    x, _ = toy_batch(spec)
    state, logits = M.forward(spec, params, zero_state(spec, 4), x)
    assert logits.shape == (6, 4, 50)
    assert all(s.shape == (4, 16) for s in state)


@pytest.mark.parametrize("kind", ["lstm", "gru"])
def test_untrained_loss_near_log_vocab(kind):
    spec = tiny_spec(kind)
    params = M.init_params(spec)
    x, y = toy_batch(spec)
    loss, _ = M.loss_fn(spec, params, zero_state(spec, 4), x, y)
    assert abs(float(loss) - np.log(50)) < 0.5


@pytest.mark.parametrize("kind,setting", [("lstm", "fp"), ("lstm", "w2a2"), ("gru", "w3a3")])
def test_train_step_reduces_loss_on_repeated_batch(kind, setting):
    w_bits, a_bits = SETTINGS[setting]
    spec = tiny_spec(kind, w_bits, a_bits)
    params = M.init_params(spec)
    x, y = toy_batch(spec, seed=3)
    step = jax.jit(M.make_train_step(spec))
    state = zero_state(spec, 4)
    losses = []
    for _ in range(8):
        params, _, loss = step(params, state, x, y, jnp.float32(2.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_weight_clip_applied():
    spec = tiny_spec("lstm")
    params = M.init_params(spec)
    params["wx"] = params["wx"] + 10.0  # force out of range
    x, y = toy_batch(spec)
    step = jax.jit(M.make_train_step(spec))
    new, _, _ = step(params, zero_state(spec, 4), x, y, jnp.float32(0.1))
    assert float(jnp.max(jnp.abs(new["wx"]))) <= 1.0 + 1e-6


def test_eval_step_counts():
    spec = tiny_spec("gru")
    params = M.init_params(spec)
    x, y = toy_batch(spec)
    ev = jax.jit(M.make_eval_step(spec))
    state, total, count = ev(params, zero_state(spec, 4), x, y)
    assert float(count) == 24.0
    assert float(total) > 0.0


def test_grad_clip_global_norm():
    grads = {"a": jnp.full((4,), 10.0), "b": jnp.full((2,), -10.0)}
    clipped = M.clip_global_norm(grads, 0.25)
    norm = float(jnp.sqrt(sum(jnp.sum(g**2) for g in clipped.values())))
    assert abs(norm - 0.25) < 1e-5


def test_quantized_forward_matches_manual_quantization():
    """STE forward must equal running the model on pre-quantized weights."""
    from compile.kernels import alt_quant

    spec_q = tiny_spec("lstm", w_bits=2, a_bits=0)
    spec_fp = tiny_spec("lstm", w_bits=0, a_bits=0)
    params = M.init_params(spec_q, seed=5)
    x, _ = toy_batch(spec_q)
    _, logits_q = M.forward(spec_q, params, zero_state(spec_q, 4), x)
    manual = dict(params)
    for name in ["embedding", "wx", "wh", "softmax_w"]:
        manual[name] = alt_quant.quantize_rows_dequant(params[name], 2)
    _, logits_m = M.forward(spec_fp, manual, zero_state(spec_fp, 4), x)
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_m), atol=1e-4)


def test_manifest_contract():
    geo = dict(vocab=100, hidden=8, batch=2, bptt=3)
    for tag in all_tags():
        spec = spec_for_tag(tag, geo)
        shapes = M.param_shapes(spec)
        assert list(shapes) == M.PARAM_ORDER
        n_args_train = len(M.PARAM_ORDER) + (2 if spec.kind == "lstm" else 1) + 3
        assert len(example_args(spec, geo, with_lr=True)) == n_args_train


def test_tensorio_roundtrip(tmp_path):
    t = {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.array([-1.0, 2.0], np.float32),
    }
    p = tmp_path / "x.amqt"
    tensorio.save(p, t)
    back = tensorio.load(p)
    assert set(back) == {"w", "b"}
    np.testing.assert_array_equal(back["w"], t["w"])
    np.testing.assert_array_equal(back["b"], t["b"])
