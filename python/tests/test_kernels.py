"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py),
including hypothesis sweeps over shapes/bits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import alt_quant, quant_matmul, ref


def rand(shape, seed=0, scale=1.0, heavy=False):
    rng = np.random.default_rng(seed)
    if heavy:
        x = rng.laplace(0.0, scale, size=shape)
    else:
        x = rng.normal(0.0, scale, size=shape)
    return jnp.asarray(x, jnp.float32)


# --- reference algorithm invariants ----------------------------------------


def test_greedy_init_k1_closed_form():
    w = rand((64,), 1)
    alphas, planes = ref.greedy_init(w, 1)
    assert np.isclose(float(alphas[0]), float(jnp.mean(jnp.abs(w))), atol=1e-6)
    np.testing.assert_array_equal(np.sign(np.asarray(planes[0])), np.sign(np.where(w >= 0, 1, -1)))


def test_lsq_refit_recovers_exact_combination():
    rng = np.random.default_rng(2)
    planes = jnp.asarray(np.sign(rng.normal(size=(2, 200))), jnp.float32)
    w = 0.6 * planes[0] + 0.25 * planes[1]
    alphas = ref.lsq_refit(w, planes)
    np.testing.assert_allclose(np.asarray(alphas), [0.6, 0.25], atol=1e-3)


@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_bst_equals_argmin(k):
    """Algorithm 1 (BST/searchsorted) and the kernel's argmin form agree."""
    w = rand((300,), 3 + k)
    alphas = jnp.abs(rand((k,), 10 + k)) + 0.05
    d_bst = ref.dequantize(alphas, ref.bst_assign(w, alphas))
    d_arg = ref.dequantize(alphas, ref.argmin_assign(w, alphas))
    # Optimal assignments achieve identical distance (patterns may differ on
    # exact ties).
    np.testing.assert_allclose(
        np.abs(np.asarray(w - d_bst)), np.abs(np.asarray(w - d_arg)), atol=1e-5
    )


def test_alternating_monotone_error():
    w = rand((512,), 5, heavy=True)
    errs = []
    for cycles in range(4):
        alphas, planes = ref.alternating_quantize(w, 2, cycles)
        errs.append(float(jnp.sum((w - ref.dequantize(alphas, planes)) ** 2)))
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-4


def test_alternating_beats_greedy():
    w = rand((2048,), 6, heavy=True)
    ga, gp = ref.greedy_init(w, 3)
    aa, ap = ref.alternating_quantize(w, 3, 2)
    eg = float(jnp.sum((w - ref.dequantize(ga, gp)) ** 2))
    ea = float(jnp.sum((w - ref.dequantize(aa, ap)) ** 2))
    assert ea < eg


# --- Pallas kernel vs oracle ------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 3])
@pytest.mark.parametrize("rows,cols", [(4, 32), (64, 200), (130, 64)])
def test_pallas_matches_ref(k, rows, cols):
    w = rand((rows, cols), rows * 31 + k, scale=0.3, heavy=True)
    got = alt_quant.quantize_rows_dequant(w, k, 2)
    want = ref.quantize_rows_dequant(w, k, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 80),
    cols=st.integers(2, 96),
    k=st.integers(1, 3),
    cycles=st.integers(0, 3),
    seed=st.integers(0, 2**16),
)
def test_pallas_matches_ref_hypothesis(rows, cols, k, cycles, seed):
    w = rand((rows, cols), seed, scale=0.5)
    got = alt_quant.quantize_rows_dequant(w, k, cycles, block=32)
    want = ref.quantize_rows_dequant(w, k, cycles)
    err_got = float(jnp.sum((w - got) ** 2))
    err_want = float(jnp.sum((w - want) ** 2))
    # Identical algorithm => identical reconstruction error (ties in the
    # argmin may pick different-but-equidistant codes).
    assert err_got <= err_want * (1 + 1e-4) + 1e-5
    assert err_want <= err_got * (1 + 1e-4) + 1e-5


def test_pallas_zero_rows_and_padding():
    w = jnp.zeros((5, 16), jnp.float32)
    out = alt_quant.quantize_rows_dequant(w, 2, 2, block=4)  # forces padding
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_ste_gradient_is_identity():
    w = rand((8, 32), 7)

    def f(w):
        return jnp.sum(alt_quant.ste(w, 2) ** 2)

    g = jax.grad(f)(w)
    # STE: d/dw sum(q(w)^2) == 2*q(w) (gradient flows as if q were identity).
    q = alt_quant.quantize_rows_dequant(w, 2)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * q), atol=1e-4)


# --- quantized matmul kernel -------------------------------------------------


@pytest.mark.parametrize("rows,n,m,k", [(16, 32, 8, 2), (100, 64, 4, 3)])
def test_quant_matmul_matches_ref(rows, n, m, k):
    w = rand((rows, n), 11, scale=0.2)
    alphas, planes = ref.quantize_rows(w, k, 2)
    x = rand((n, m), 13)
    got = quant_matmul.quantized_matmul(alphas, planes, x, block_r=32)
    want = ref.quantized_matmul(alphas, planes, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-3)
